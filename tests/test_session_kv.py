"""Session-level KV-cache reuse: incremental prefill equivalence, the LRU
session pool, prefix-mismatch fallback, and the context-overflow guard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelConfig,
    decode_step,
    init_params,
    prefill,
    prefill_append,
    supports_append,
)
from repro.serving import JaxLLMService, SessionCachePool
from repro.serving.session_cache import CacheEntry, longest_common_prefix


@pytest.fixture(scope="module")
def cfg():
    return ModelConfig(
        name="tiny-kv", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=4096, param_dtype="float32",
        compute_dtype="float32",
    )


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.key(0), cfg)


def _greedy(params, cfg, logits, caches, pos, n=10):
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(n):
        out.append(int(tok[0]))
        logits, caches = decode_step(params, cfg, caches, tok[:, None], pos)
        pos = pos + 1
        tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Model layer: prefill_append ≡ from-scratch prefill
# ---------------------------------------------------------------------------

@pytest.mark.slow  # ~16s of one-off prefill/append shape compiles
def test_append_matches_full_prefill(cfg, params):
    """From-scratch prefill of ctx+suffix and cached-prefix + chunked append
    must agree: same kv_pos, same greedy continuation."""
    rng = np.random.default_rng(7)
    ctx = rng.integers(0, cfg.vocab_size, size=40).tolist()
    suf = rng.integers(0, cfg.vocab_size, size=17).tolist()
    max_len = 128

    full = jnp.asarray(np.array(ctx + suf, np.int32)[None])
    lf, cf, pf = prefill(params, cfg, full, max_len=max_len)

    # cached path: prefill the prefix, then append the suffix in two chunks
    # (one exact-size, one right-padded with true_len masking)
    la, ca, pa = prefill(params, cfg, jnp.asarray(np.array(ctx, np.int32)[None]),
                         max_len=max_len)
    c1 = jnp.asarray(np.array(suf[:10], np.int32)[None])
    la, ca, pa = prefill_append(params, cfg, ca, c1, p0=pa)
    padded = np.zeros((1, 8), np.int32)
    padded[0, :7] = suf[10:]
    la, ca, pa = prefill_append(params, cfg, ca, jnp.asarray(padded), p0=pa,
                                true_len=jnp.array([7], jnp.int32))

    assert int(pf[0]) == int(pa[0]) == len(ctx) + len(suf)
    assert jnp.array_equal(cf[0]["kv_pos"], ca[0]["kv_pos"])
    # K/V must match on every valid slot (invalid slots may hold masked junk)
    valid = (cf[0]["kv_pos"] >= 0)[None, :, :, None, None]
    assert float(jnp.max(jnp.abs(jnp.where(valid, cf[0]["k"] - ca[0]["k"], 0)))) < 1e-4
    np.testing.assert_allclose(np.asarray(lf), np.asarray(la), atol=1e-4)
    assert _greedy(params, cfg, lf, cf, pf) == _greedy(params, cfg, la, ca, pa)


@pytest.mark.slow
def test_append_rejects_unsupported_arch():
    ssm_cfg = ModelConfig(
        name="tiny-ssm", arch_type="ssm", n_layers=2, d_model=64, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab_size=512, ssm_state=16, ssm_head_dim=32,
        ssm_chunk=4, param_dtype="float32", compute_dtype="float32",
    )
    assert not supports_append(ssm_cfg)
    params = init_params(jax.random.key(0), ssm_cfg)
    toks = jnp.zeros((1, 4), jnp.int32)
    _, caches, pos = prefill(params, ssm_cfg, toks, max_len=32)
    with pytest.raises(AssertionError):
        prefill_append(params, ssm_cfg, caches, toks, p0=pos)


# ---------------------------------------------------------------------------
# Serving layer: end-to-end reuse equivalence + overflow guard
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def services(cfg):
    reuse = JaxLLMService.create("tiny-kv", cfg, max_len=512)
    scratch = JaxLLMService.create("tiny-kv", cfg, max_len=512, kv_reuse=False)
    return reuse, scratch


@pytest.mark.slow
def test_cached_prefill_identical_generations(services):
    """Cache-hit turns must generate exactly what a from-scratch prefill
    generates, while prefilling only the new-token suffix."""
    reuse, scratch = services
    tok = reuse.tokenizer
    ctx_a, ctx_b = [], []
    for turn in range(3):
        p = tok.encode(f"turn {turn}: describe the robot sensor stack")
        ra = reuse.completion(ctx_a, p, 8, cache_key="sess-eq")
        rb = scratch.completion(ctx_b, p, 8)
        assert ra.token_ids == rb.token_ids
        if turn == 0:
            assert not ra.cache_hit
        else:
            assert ra.cache_hit
            assert ra.reused_tokens == len(ctx_a)
            assert ra.prefill_tokens == len(p)
        ctx_a = ctx_a + p + ra.token_ids
        ctx_b = ctx_b + p + rb.token_ids


def test_prefix_mismatch_falls_back_to_full_prefill(services):
    """Edited/stale history must invalidate the cached prefix and produce
    the same output as a from-scratch service."""
    reuse, scratch = services
    tok = reuse.tokenizer
    p0 = tok.encode("first question about lidar")
    r0 = reuse.completion([], p0, 8, cache_key="sess-mm")
    ctx = p0 + r0.token_ids
    edited = list(ctx)
    edited[2] = (edited[2] + 1) % reuse.engine.cfg.vocab_size  # history edit
    p1 = tok.encode("second question about odometry")
    inv_before = reuse.engine.session_pool.invalidations
    r1 = reuse.completion(edited, p1, 8, cache_key="sess-mm")
    assert not r1.cache_hit
    assert r1.prefill_tokens == len(edited) + len(p1)
    assert reuse.engine.session_pool.invalidations == inv_before + 1
    rs = scratch.completion(edited, p1, 8)
    assert r1.token_ids == rs.token_ids


def test_windowed_decode_matches_per_token_sync(services):
    """Device-side stop scanning (sync every k) must not change outputs."""
    reuse, _ = services
    ids = reuse.tokenizer.encode("compare the two mapping approaches")
    orig = reuse.engine.sync_every
    try:
        reuse.engine.sync_every = 1
        a = reuse.engine.generate(ids, max_new_tokens=12)
        reuse.engine.sync_every = 5
        b = reuse.engine.generate(ids, max_new_tokens=12)
    finally:
        reuse.engine.sync_every = orig
    assert a == b


def test_context_overflow_truncates_oldest(services):
    """Near-max_len context must not trip the generate assert: the oldest
    context tokens are dropped, the prompt is kept."""
    reuse, _ = services
    tok = reuse.tokenizer
    big_ctx = tok.encode("history filler words " * 400)
    assert len(big_ctx) > reuse.engine.max_len
    prompt = tok.encode("what did we just discuss?")
    r = reuse.completion(big_ctx, prompt, 16, cache_key="sess-ovf")
    assert len(r.token_ids) >= 1
    assert r.prefill_tokens + r.reused_tokens < reuse.engine.max_len


# ---------------------------------------------------------------------------
# Pool mechanics (pure python — no device work)
# ---------------------------------------------------------------------------

def _entry(ids):
    return CacheEntry(token_ids=list(ids), caches=[])


def test_lcp():
    assert longest_common_prefix([1, 2, 3], [1, 2, 4]) == 2
    assert longest_common_prefix([], [1]) == 0
    assert longest_common_prefix([1, 2], [1, 2]) == 2


def test_pool_lru_eviction():
    pool = SessionCachePool(capacity=2)
    pool.put("a", _entry([1, 2]))
    pool.put("b", _entry([3, 4]))
    pool.put("c", _entry([5, 6]))          # evicts "a" (LRU)
    assert pool.evictions == 1
    assert "a" not in pool and "b" in pool and "c" in pool
    pool.match("b", [3, 4, 9])             # touch "b" -> "c" is now LRU
    pool.put("d", _entry([7, 8]))          # evicts "c" (b was refreshed)
    assert "b" in pool and "c" not in pool


def test_pool_mismatch_invalidates():
    pool = SessionCachePool(capacity=2)
    pool.put("s", _entry([1, 2, 3]))
    entry, usable = pool.match("s", [1, 9, 3, 4])   # diverges at index 1
    assert entry is None and usable == 0
    assert pool.invalidations == 1 and "s" not in pool


def test_pool_match_leaves_one_token_to_compute():
    pool = SessionCachePool(capacity=2)
    pool.put("s", _entry([1, 2, 3]))
    entry, usable = pool.match("s", [1, 2, 3])      # identical resend
    assert entry is not None and usable == 2        # last token recomputed
    entry, usable = pool.match("s", [1, 2, 3, 4, 5])
    assert entry is not None and usable == 3


def test_pool_shorter_incoming_reuses_with_trim():
    """A client retry resends a prefix of the cached tokens — that is not a
    divergence: the matching head is reusable (caller trims kv_pos)."""
    pool = SessionCachePool(capacity=2)
    pool.put("s", _entry([1, 2, 3, 4]))
    entry, usable = pool.match("s", [1, 2])
    assert entry is not None and usable == 1        # reuse [1], recompute [2]
    assert pool.invalidations == 0 and "s" in pool


def test_engine_resend_identical_request(services):
    """Resending the exact same request (client retry) must reuse the cached
    prefix and reproduce the same generation."""
    reuse, scratch = services
    tok = reuse.tokenizer
    ctx = tok.encode("a conversation about wheel odometry calibration")
    p = tok.encode("and what about slip compensation?")
    r1 = reuse.completion(ctx, p, 8, cache_key="sess-rs")
    r2 = reuse.completion(ctx, p, 8, cache_key="sess-rs")
    rs = scratch.completion(ctx, p, 8)
    assert r2.cache_hit and r2.reused_tokens == len(ctx) + len(p) - 1
    assert r1.token_ids == r2.token_ids == rs.token_ids
