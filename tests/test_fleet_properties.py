"""Property tests for the fleet scenario engine: the workload generator is
a pure function of its spec — same seed, same trace, byte for byte. Every
policy comparison in benchmarks/fleet_bench.py rests on this.
"""

from _hypothesis_support import given, settings, st

from repro.fleet import SessionPlan, WorkloadSpec, generate_workload

specs = st.builds(
    WorkloadSpec,
    n_clients=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    arrival_rate_per_s=st.floats(min_value=0.5, max_value=50.0),
    diurnal_amplitude=st.floats(min_value=0.0, max_value=0.95),
    pareto_alpha=st.floats(min_value=0.8, max_value=3.0),
    max_turns=st.integers(min_value=1, max_value=16),
    n_families=st.integers(min_value=1, max_value=32),
    zipf_s=st.floats(min_value=0.5, max_value=2.0),
)


@settings(max_examples=25, deadline=None)
@given(spec=specs)
def test_same_seed_gives_identical_trace(spec):
    a = generate_workload(spec)
    b = generate_workload(spec)
    assert a == b                       # dataclass equality: full trace


@settings(max_examples=25, deadline=None)
@given(spec=specs)
def test_trace_shape_invariants(spec):
    plans = generate_workload(spec)
    assert len(plans) == spec.n_clients
    for p in plans:
        assert isinstance(p, SessionPlan)
        assert p.start_ms >= 0
        assert 1 <= len(p.prompts) <= spec.max_turns
        assert 0 <= p.family < spec.n_families
        assert p.think_ms >= spec.think_ms_min
    # arrivals come out of the Poisson process already ordered
    starts = [p.start_ms for p in plans]
    assert starts == sorted(starts)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1000),
    other=st.integers(min_value=1001, max_value=2000),
)
def test_different_seeds_give_different_arrivals(seed, other):
    base = WorkloadSpec(n_clients=16, seed=seed)
    moved = WorkloadSpec(n_clients=16, seed=other)
    a = [p.start_ms for p in generate_workload(base)]
    b = [p.start_ms for p in generate_workload(moved)]
    assert a != b


def test_generator_is_deterministic_without_hypothesis():
    """Deterministic twin of the property so the guarantee is checked even
    when hypothesis is not installed."""
    spec = WorkloadSpec(n_clients=24, seed=42)
    assert generate_workload(spec) == generate_workload(spec)
    assert generate_workload(spec) != generate_workload(
        WorkloadSpec(n_clients=24, seed=43)
    )
