"""Tokenizer substrate tests: determinism, roundtrip, wire formats."""

import numpy as np
import pytest

from _hypothesis_support import given, settings, st

from repro.tokenizer import (
    ByteLevelBPE,
    IM_END,
    IM_START,
    NL,
    encode_conversation,
    encode_turn,
    get_tokenizer,
    render_conversation,
)

TEXT = st.text(
    alphabet=st.characters(codec="utf-8", exclude_characters="\x00"),
    max_size=200,
)


@pytest.fixture(scope="module")
def tok():
    return get_tokenizer(65536, seed=3)


def test_roundtrip_simple(tok):
    s = "What are the fundamental components of an autonomous mobile robot?"
    assert tok.decode(tok.encode(s)) == s


@settings(max_examples=80, deadline=None)
@given(TEXT)
def test_roundtrip_property(s):
    tok = get_tokenizer(65536, seed=3)
    assert tok.decode(tok.encode(s)) == s


def test_deterministic_across_instances():
    a = ByteLevelBPE(vocab_size=2048, seed=9)
    b = ByteLevelBPE(vocab_size=2048, seed=9)
    s = "sensor fusion with particle filters"
    assert a.encode(s) == b.encode(s)


def test_different_seeds_differ():
    a = ByteLevelBPE(vocab_size=65536, seed=1)
    b = ByteLevelBPE(vocab_size=65536, seed=2)
    s = "the robot sensor controller state estimation"
    assert a.encode(s) != b.encode(s)


def test_ids_below_vocab(tok):
    ids = tok.encode("control systems for autonomous robots " * 20)
    assert max(ids) < tok.vocab_size


def test_token_serialization_roundtrip(tok):
    ids = tok.encode("distributed context management at the edge")
    raw = tok.serialize_tokens(ids)
    assert tok.deserialize_tokens(raw) == ids
    assert len(raw) == len(ids) * tok.token_nbytes


def test_tight_token_packing():
    assert get_tokenizer(32000, seed=0).token_nbytes == 2
    assert get_tokenizer(151936, seed=0).token_nbytes == 3   # fits 2^24
    assert get_tokenizer(256000, seed=0).token_nbytes == 3
    big = get_tokenizer(151936, seed=0)
    ids = big.encode("pack me tightly " * 10)
    assert big.deserialize_tokens(big.serialize_tokens(ids)) == ids


def test_chat_template_structure(tok):
    ids = encode_turn(tok, "user", "hello")
    assert ids[0] == IM_START and IM_END in ids and ids[-1] == NL
    conv = encode_conversation(tok, [("user", "a"), ("assistant", "b")])
    assert conv.count(IM_START) == 2


def test_encode_cost_linear(tok):
    """Raw-mode re-tokenization cost must grow with history length —
    the mechanical basis of the paper's Fig. 3 effect."""
    import time

    base = "context token latency bandwidth storage replica turn counter "
    tok._word_cache.clear()
    t0 = time.perf_counter()
    tok.encode(base * 50)
    t_small = time.perf_counter() - t0
    t0 = time.perf_counter()
    tok.encode(base * 2000)
    t_big = time.perf_counter() - t0
    assert t_big > t_small * 5  # superlinear headroom over 40x input
