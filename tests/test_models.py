"""Model-zoo correctness: decode-with-cache == full forward, pallas == ref,
bucketed prefill, VLM/audio specifics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelConfig,
    decode_step,
    forward_full,
    init_params,
    prefill,
)

BASE = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
    param_dtype="float32", compute_dtype="float32",
)

FAMILIES = {
    "dense": dict(arch_type="dense"),
    "qkv-bias": dict(arch_type="dense", qkv_bias=True),
    "moe": dict(arch_type="moe", n_experts=4, top_k=2, capacity_factor=4.0),
    "gemma": dict(
        arch_type="dense", layer_pattern="local_global", sliding_window=16,
        attn_softcap=50.0, logit_softcap=30.0, mlp_type="geglu",
    ),
    "sw-variant": dict(arch_type="dense", attn_variant="sliding_window", sliding_window=16),
    "mamba": dict(
        arch_type="ssm", ssm_state=16, ssm_head_dim=32, ssm_chunk=4,
        n_heads=0, n_kv_heads=0, d_ff=0,
    ),
    "zamba": dict(
        arch_type="hybrid", layer_pattern="zamba_hybrid", shared_attn_period=2,
        ssm_state=16, ssm_head_dim=32, ssm_chunk=4, n_layers=5,
    ),
    "chatglm": dict(arch_type="dense", rope_style="chatglm2d"),
    "relu2": dict(arch_type="dense", mlp_type="relu2"),
}


def make_cfg(name):
    kw = {**BASE, **FAMILIES[name]}
    return ModelConfig(name=name, **kw)


@pytest.mark.slow  # ~60s across families: full forward + T decode steps each
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_decode_matches_forward(family):
    cfg = make_cfg(family)
    B, S = 2, 24
    params = init_params(jax.random.key(1), cfg)
    toks = jax.random.randint(jax.random.key(2), (B, S + 4), 0, cfg.vocab_size)
    logits_full, _ = forward_full(params, cfg, toks)
    lg, caches, pos = prefill(params, cfg, toks[:, :S], max_len=S + 8)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_full[:, S - 1]), rtol=3e-4, atol=3e-4
    )
    for i in range(4):
        lg2, caches = decode_step(params, cfg, caches, toks[:, S + i : S + i + 1], pos)
        np.testing.assert_allclose(
            np.asarray(lg2[:, 0]), np.asarray(logits_full[:, S + i]),
            rtol=5e-4, atol=5e-4,
        )
        pos = pos + 1


@pytest.mark.slow
def test_bucketed_prefill_matches_exact():
    cfg = make_cfg("dense")
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 20), 0, cfg.vocab_size)
    lg_exact, _, pos_e = prefill(params, cfg, toks, max_len=64)
    padded = jnp.pad(toks, [(0, 0), (0, 12)])
    lg_bucket, caches, pos_b = prefill(
        params, cfg, padded, max_len=64, true_len=jnp.array([20], jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(lg_bucket), np.asarray(lg_exact), rtol=1e-5, atol=1e-5)
    assert int(pos_b[0]) == 20


@pytest.mark.slow
def test_vlm_patch_embeds_change_output():
    cfg = ModelConfig(
        name="vlm", arch_type="vlm", rope_style="mrope", mrope_sections=(2, 3, 3),
        n_patches=8, **BASE,
    )
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab_size)
    pe1 = jnp.zeros((1, 8, cfg.d_model))
    pe2 = jnp.ones((1, 8, cfg.d_model))
    l1, _ = forward_full(params, cfg, toks, patch_embeds=pe1)
    l2, _ = forward_full(params, cfg, toks, patch_embeds=pe2)
    assert not bool(jnp.allclose(l1, l2))


@pytest.mark.slow
def test_audio_codebook_logits_shape():
    cfg = ModelConfig(name="audio", arch_type="audio", n_codebooks=4, **BASE)
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16, 4), 0, cfg.vocab_size)
    logits, _ = forward_full(params, cfg, toks)
    assert logits.shape == (2, 16, 4, cfg.vocab_size)


@pytest.mark.slow
def test_sliding_window_limits_attention():
    """With window W, logits at position p must not depend on tokens < p-W."""
    cfg = make_cfg("sw-variant")
    params = init_params(jax.random.key(0), cfg)
    t1 = jax.random.randint(jax.random.key(1), (1, 48), 0, cfg.vocab_size)
    t2 = t1.at[:, 0:8].set((t1[:, 0:8] + 7) % cfg.vocab_size)  # differ only early
    l1, _ = forward_full(params, cfg, t1)
    l2, _ = forward_full(params, cfg, t2)
    # last position attends only to the trailing 16 tokens ... but early tokens
    # propagate through layer stacking (2 layers x window 16 reach = 32 < 40)
    np.testing.assert_allclose(
        np.asarray(l1[:, -1]), np.asarray(l2[:, -1]), rtol=1e-4, atol=1e-4
    )


@pytest.mark.slow
def test_moe_router_balance_loss_positive():
    cfg = make_cfg("moe")
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    _, aux = forward_full(params, cfg, toks)
    assert float(aux) > 0.0


@pytest.mark.slow
def test_remat_matches_no_remat():
    cfg = make_cfg("dense")
    cfg_nr = cfg.replace(remat=False)
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 16), 0, cfg.vocab_size)
    l1, _ = forward_full(params, cfg, toks)
    l2, _ = forward_full(params, cfg_nr, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6, atol=1e-6)
