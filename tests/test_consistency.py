"""Turn-counter consistency protocol: unit + hypothesis property tests.

The property tests drive random mobility traces (node choice, link latency,
think times) and assert the system's invariants:
- STRONG policy never serves context older than the client's turn counter;
- responses depend on the full context (no silent truncation);
- the store converges (eventual consistency) once in-flight sync drains;
- monotonic reads / read-your-writes hold per session.
"""

import pytest

from _hypothesis_support import given, settings, st

from repro.core import (
    ConsistencyPolicy,
    ContextMode,
    RetryPolicy,
    StaleContextError,
    check_monotonic_reads,
    check_read_your_writes,
    read_with_turn_check,
)
from repro.edge import EchoLLMService, EdgeCluster, LLMClient
from repro.store import DistributedKVStore, Link, Network


def build(n_nodes=3, latency=3.0, bw=100.0, retry=None, replication="full",
          client_latency=None):
    return EdgeCluster.build(
        [f"n{i}" for i in range(n_nodes)],
        lambda nid: EchoLLMService(model="m", vocab_size=32000),
        inter_node_link=Link(latency_ms=latency, bandwidth_mbps=bw),
        client_link=(
            Link(latency_ms=client_latency, bandwidth_mbps=1000.0)
            if client_latency is not None else None
        ),
        retry=retry,
        replication=replication,
    )


def test_fresh_session_no_retries():
    cluster = build()
    client = LLMClient(cluster, model="m")
    r = client.chat("hello robots", "n0")
    assert r.error is None and r.timing.retries == 0 and r.turn == 1


def test_roaming_waits_for_replication():
    # slow peer sync (20ms) + fast client path (1ms): the roamed-to node's
    # replica is ~18ms behind -> ~2 retries of 10ms backoff
    cluster = build(latency=20.0, client_latency=1.0)
    client = LLMClient(cluster, model="m")
    client.chat("first question about sensors", "n0")
    r = client.chat("second question about that", "n1")  # immediate roam
    assert r.error is None
    assert r.timing.retries >= 1          # had to wait for sync
    assert r.n_context_tokens > 0          # got the full context


def test_strong_policy_raises_when_unreachable():
    retry = RetryPolicy(max_retries=2, backoff_ms=1.0)
    # replication can never land in time; client path is fast
    cluster = build(latency=1e6, retry=retry, client_latency=1.0)
    client = LLMClient(cluster, model="m")
    client.chat("first", "n0")
    r = client.chat("second", "n1")
    assert r.error is not None and "turn" in r.error


def test_available_policy_serves_stale():
    retry = RetryPolicy(max_retries=1, backoff_ms=1.0)
    cluster = build(latency=1e6, retry=retry, client_latency=1.0)
    client = LLMClient(
        cluster, model="m", policy=ConsistencyPolicy.AVAILABLE
    )
    client.chat("first", "n0")
    r = client.chat("second", "n1")
    assert r.error is None and r.stale


def test_context_grows_per_turn():
    cluster = build()
    client = LLMClient(cluster, model="m")
    sizes = []
    for i in range(4):
        r = client.chat(f"question {i}", "n0")
        sizes.append(r.n_context_tokens)
        client.think(500)
    assert sizes == sorted(sizes) and sizes[-1] > sizes[0]


def test_client_side_mode_never_touches_store():
    cluster = build()
    client = LLMClient(cluster, model="m", mode=ContextMode.CLIENT_SIDE)
    for i in range(3):
        client.chat(f"q{i}", f"n{i % 2}")
    cluster.converge()
    assert cluster.sync_bytes() == 0       # paper §4.1: no sync in client mode


def test_guarantee_checkers():
    assert check_monotonic_reads([0, 1, 1, 3])
    assert not check_monotonic_reads([2, 1])
    assert check_read_your_writes([1, 2], [1, 2])
    assert not check_read_your_writes([1, 2], [1, 1])


@settings(max_examples=25, deadline=None)
@given(
    moves=st.lists(st.integers(0, 2), min_size=2, max_size=8),
    latency=st.floats(0.5, 25.0),
    think=st.floats(0.0, 120.0),
)
def test_property_strong_never_stale(moves, latency, think):
    """Random mobility trace: strong consistency either serves the exact
    turn or errors — never silently stale."""
    cluster = build(latency=latency)
    client = LLMClient(cluster, model="m")
    versions_seen = []
    for i, node in enumerate(moves):
        r = client.chat(f"question {i} about slam", f"n{node}")
        if r.error is not None:
            # allowed only if replication genuinely couldn't land in budget
            assert r.timing.retries == 0 or True
            break
        assert not r.stale
        # server context version == client turn before this request
        versions_seen.append(r.turn)
        client.think(think)
    assert check_monotonic_reads(versions_seen)


@settings(max_examples=15, deadline=None)
@given(
    moves=st.lists(st.integers(0, 2), min_size=2, max_size=6),
    latency=st.floats(0.5, 10.0),
)
def test_property_convergence(moves, latency):
    """After draining the network, every replica in the keygroup holds the
    latest version."""
    cluster = build(latency=latency)
    client = LLMClient(cluster, model="m")
    last_turn = 0
    for i, node in enumerate(moves):
        r = client.chat(f"q{i}", f"n{node}")
        if r.error:
            break
        last_turn = r.turn
        client.think(200.0)
    cluster.converge()
    if last_turn and client.user_id:
        from repro.core.session import context_key

        key = context_key(client.user_id, client.session_id)
        for n in ("n0", "n1", "n2"):
            vv = cluster.store.get(n, "m", key)
            assert vv is not None and vv.version == last_turn


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 1), min_size=3, max_size=7))
def test_property_responses_depend_on_context(moves):
    """The echo service hashes its full input: if two clients with different
    histories ask the same question, answers must differ — proving the
    context actually reaches the model after roaming."""
    cluster = build(latency=1.0)
    a = LLMClient(cluster, model="m")
    b = LLMClient(cluster, model="m")
    a.chat("seed question alpha about lidar", "n0")
    b.chat("seed question beta about radar", "n0")
    a.think(100); b.think(100)
    ra = [a.chat(f"common q {i}", f"n{m}") for i, m in enumerate(moves)]
    rb = [b.chat(f"common q {i}", f"n{m}") for i, m in enumerate(moves)]
    assert any(x.text != y.text for x, y in zip(ra, rb))
