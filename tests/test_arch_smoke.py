"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated in its REDUCED variant (≤2
layers, d_model ≤ 512, ≤4 experts) and runs one forward pass + one train
step + one prefill/decode step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # ~80s: one compile per assigned architecture

from repro.configs import ASSIGNED, get_config
from repro.data import BatchIterator
from repro.models import (
    decode_step,
    forward_full,
    init_params,
    prefill,
)
from repro.training import OptConfig, init_opt_state, make_train_step

ARCHS = sorted(ASSIGNED)


def _inputs(cfg, B=2, S=32):
    key = jax.random.key(0)
    if cfg.n_codebooks > 1:
        toks = jax.random.randint(key, (B, S, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pe = (
        jax.random.normal(key, (B, cfg.n_patches, cfg.d_model))
        if cfg.n_patches
        else None
    )
    return toks, pe


@pytest.fixture(scope="module")
def reduced(request):
    pass


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.key(0), cfg)
    toks, pe = _inputs(cfg)
    logits, aux = forward_full(params, cfg, toks, patch_embeds=pe)
    want = (2, 32, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks > 1 else (
        2, 32, cfg.vocab_size
    )
    assert logits.shape == want
    assert not bool(jnp.isnan(logits).any())
    if cfg.n_experts:
        assert float(aux) > 0.0  # router load-balance loss is live


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.key(0), cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)))
    batch = next(BatchIterator(cfg, batch_size=2, seq_len=32))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    if cfg.n_patches:
        batch["patch_embeds"] = jnp.zeros((2, cfg.n_patches, cfg.d_model), jnp.float32)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # parameters actually moved
    moved = any(
        not bool(jnp.allclose(a, b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.key(0), cfg)
    toks, pe = _inputs(cfg)
    logits, caches, pos = prefill(params, cfg, toks, max_len=40, patch_embeds=pe)
    assert not bool(jnp.isnan(logits).any())
    step_tok = toks[:, :1]
    lg, caches = decode_step(params, cfg, caches, step_tok, pos)
    want = (2, 1, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks > 1 else (
        2, 1, cfg.vocab_size
    )
    assert lg.shape == want
    assert not bool(jnp.isnan(lg).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_respects_limits(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 or cfg.layer_pattern == "zamba_hybrid"
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


def test_registry_roundtrip():
    for arch in ARCHS:
        assert get_config(arch).name == arch
    with pytest.raises(KeyError):
        get_config("nope-3b")
