"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benchmarks must see the single real CPU device; only
launch/dryrun.py (run as its own process) forces 512 host devices."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    from repro.models import ModelConfig

    return ModelConfig(
        name="tiny-dense",
        arch_type="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
    )
