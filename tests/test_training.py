"""Training substrate: optimizer math, schedule, accumulation, checkpoint."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # multi-minute: jitted train steps + checkpoint roundtrips

from repro.data import BatchIterator
from repro.models import ModelConfig, init_params
from repro.training import (
    OptConfig,
    adamw_update,
    init_opt_state,
    load_checkpoint,
    make_train_step,
    save_checkpoint,
    schedule,
)


def test_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_adamw_moves_toward_gradient():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
    new, state, m = adamw_update(params, grads, state, cfg)
    assert bool(jnp.all(new["w"] < params["w"]))
    assert float(m["grad_norm"]) == pytest.approx(2.0)


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, grad_clip=1.0, weight_decay=0.0)
    new1, _, _ = adamw_update(params, grads, state, cfg)
    new2, _, _ = adamw_update(params, {"w": jnp.full((4,), 1000.0)}, state, cfg)
    # clipped: same effective update direction/scale
    np.testing.assert_allclose(np.asarray(new1["w"]), np.asarray(new2["w"]), rtol=1e-5)


def _tiny_cfg(**kw):
    base = dict(
        name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, param_dtype="float32",
        compute_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def test_loss_decreases():
    cfg = _tiny_cfg()
    params = init_params(jax.random.key(0), cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=2e-3, warmup_steps=3, total_steps=60)))
    it = BatchIterator(cfg, batch_size=4, seq_len=32)
    losses = []
    for _ in range(15):
        b = next(it)
        params, opt, m = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_grad_accum_matches_full_batch():
    """accum=2 over the same data must match accum=1 up to fp tolerance."""
    cfg1 = _tiny_cfg(grad_accum=1)
    cfg2 = _tiny_cfg(grad_accum=2)
    params = init_params(jax.random.key(0), cfg1)
    batch = next(BatchIterator(cfg1, batch_size=4, seq_len=16))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    from repro.training.trainer import grads_fn

    l1, _, g1 = grads_fn(params, cfg1, batch)
    l2, _, g2 = grads_fn(params, cfg2, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-4, atol=2e-5
        )


def test_checkpoint_roundtrip_bf16():
    tree = {
        "a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.float32)},
    }
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ck.msgpack")
        save_checkpoint(p, tree, step=7)
        restored, step = load_checkpoint(p, tree)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert x.dtype == y.dtype
        assert bool(jnp.all(x == y))


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ck.msgpack")
        save_checkpoint(p, tree)
        bad = {"a": jnp.ones((3,))}
        with pytest.raises(ValueError):
            load_checkpoint(p, bad)
