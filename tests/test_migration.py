"""Migration-aware KV warm-start across keygroup peers.

Covers the replication-arrival hook end to end: a client roams A→B
mid-session and B's turn prefills only the new-token suffix (eager prime),
greedy outputs stay identical to the cold path, and the ``migrated`` /
``kv_warm_start`` counters surface through Timing/ServiceResult. Fast tests
run on the analytic echo service; the real-engine equivalence tests carry
``@pytest.mark.slow``. See docs/architecture.md, "Migration warm-start".
"""

import jax
import pytest

from repro.core import ContextMode
from repro.edge import EchoLLMService, EdgeCluster, LLMClient
from repro.models import ModelConfig, init_params
from repro.serving import BatchedServer, CacheEntry, JaxLLMService, SessionCachePool
from repro.store import Link
from repro.tokenizer import get_tokenizer


def _echo_cluster(warm_start, kv_reuse=True):
    return EdgeCluster.build(
        ["a", "b"],
        lambda nid: EchoLLMService(model="m", vocab_size=32000, kv_reuse=kv_reuse),
        inter_node_link=Link(latency_ms=2.0, bandwidth_mbps=100.0),
        client_link=Link(latency_ms=5.0, bandwidth_mbps=50.0),
        warm_start=warm_start,
    )


def _roam(cluster, nodes, max_new_tokens=24):
    client = LLMClient(cluster, model="m", mode=ContextMode.TOKENIZED,
                       max_new_tokens=max_new_tokens)
    resps = []
    for i, node in enumerate(nodes):
        r = client.chat(f"question {i} about robots", node)
        assert r.error is None, r.error
        resps.append(r)
        client.think(400)  # lets replication (and the prime) land
    return resps


# ---------------------------------------------------------------------------
# Cluster-level warm-start semantics (analytic service — fast)
# ---------------------------------------------------------------------------

def test_roam_turn_is_warm_start():
    """A→B roam with eager warm-start: B's turn is a primed hit that
    prefills only the prompt, and both counters surface in Timing."""
    cluster = _echo_cluster("eager")
    r1, r2, r3 = _roam(cluster, ["a", "a", "b"])
    assert not r1.timing.migrated and not r2.timing.migrated
    assert not r1.timing.kv_warm_start and not r2.timing.kv_warm_start
    assert r2.timing.kv_cache_hit  # same-node hit, served (not primed) prefix

    t3 = r3.timing
    assert t3.migrated and t3.kv_cache_hit and t3.kv_warm_start
    assert t3.kv_reused_tokens == r3.n_context_tokens
    assert t3.prefill_tokens == r3.n_prompt_tokens
    assert cluster.node("b").warm_starts >= 1
    assert cluster.node("b").warm_start_ms >= 0.0


def test_roam_without_warm_start_is_cold():
    """warm_start="off": the node switch is a pool miss + full re-prefill
    (the PR-1 baseline this PR removes)."""
    cluster = _echo_cluster("off")
    _, _, r3 = _roam(cluster, ["a", "a", "b"])
    t3 = r3.timing
    assert t3.migrated and not t3.kv_cache_hit and not t3.kv_warm_start
    assert t3.prefill_tokens == r3.n_context_tokens + r3.n_prompt_tokens
    assert cluster.warm_starts() == 0


def test_roam_back_is_warm_via_delta_prime():
    """Roaming back to A after a turn on B: B's write replicated to A and
    delta-extended A's entry, so A's turn prefills only the prompt. The
    extended entry keeps its "serve" provenance — most of the reused prefix
    was served on A itself, so the turn must NOT count as a migration warm
    start (kv_warm_start inflation regression)."""
    cluster = _echo_cluster("eager")
    resps = _roam(cluster, ["a", "a", "b", "a"])
    t4 = resps[3].timing
    assert t4.migrated and t4.kv_cache_hit
    assert not t4.kv_warm_start  # provenance preserved on delta-extension
    assert t4.prefill_tokens == resps[3].n_prompt_tokens
    assert cluster.node("a").warm_starts >= 1


def test_fresh_prime_still_counts_warm_start_after_extension():
    """The provenance fix must not swallow genuine warm starts: a first
    roam onto a node whose entry was installed (and later extended) by
    primes alone still reports kv_warm_start."""
    cluster = _echo_cluster("eager")
    resps = _roam(cluster, ["a", "a", "b"])
    t3 = resps[2].timing
    # b's entry came from primes only (turn-1 install + turn-2 extension)
    assert t3.migrated and t3.kv_cache_hit and t3.kv_warm_start


def test_warm_start_cheaper_than_cold_on_analytic_clock():
    """The analytic cost model charges only the suffix on a warm roam —
    the roam turn is strictly cheaper than the cold cluster's."""
    warm = _roam(_echo_cluster("eager"), ["a", "a", "b"])
    cold = _roam(_echo_cluster("off"), ["a", "a", "b"])
    assert warm[2].timing.inference_ms < cold[2].timing.inference_ms
    # non-roam turns cost the same in both clusters
    assert warm[0].timing.inference_ms == cold[0].timing.inference_ms


def test_raw_context_never_primes():
    """RAW mode replicates text, not tokens — nothing to prefill, so the
    hook must not prime (and must not crash on RawContext values)."""
    cluster = _echo_cluster("eager")
    client = LLMClient(cluster, model="m", mode=ContextMode.RAW)
    for node in ["a", "a", "b"]:
        r = client.chat("hello", node)
        assert r.error is None
        client.think(400)
    assert cluster.warm_starts() == 0


def test_kv_reuse_disabled_service_reports_full_prefill():
    """An echo service without kv_reuse mirrors JaxLLMService(kv_reuse=False):
    no hits, prefill_tokens = full input."""
    cluster = _echo_cluster("eager", kv_reuse=False)
    _, _, r3 = _roam(cluster, ["a", "a", "b"])
    t3 = r3.timing
    assert t3.migrated and not t3.kv_cache_hit
    assert t3.prefill_tokens == r3.n_context_tokens + r3.n_prompt_tokens
    assert cluster.warm_starts() == 0  # prime() declines without a pool


def test_stale_delivery_does_not_notify():
    """A replicated write that loses last-writer-wins must not fire the
    warm-start hook (no prime for stale context)."""
    cluster = _echo_cluster("eager")
    _roam(cluster, ["a", "b", "a", "b"])
    store = cluster.store
    before = cluster.warm_starts()
    # replay: out-of-date version delivered to b is dropped, not notified
    key_vv = list(store.replica("a", "m").items())
    assert key_vv, "session context must exist on a"
    key, vv = key_vv[0]
    stale_before = store.dropped_stale_applies
    assert not store.replica("b", "m").apply_replicated(
        key, type(vv)(vv.value, 0, 0.0, None, "a")
    )
    assert cluster.warm_starts() == before
    assert store.dropped_stale_applies == stale_before  # direct apply path


def test_low_priority_update_keeps_lru_position():
    """Regression: a prime that delta-extends a key already hot in the pool
    must keep that key's LRU position. The old behavior moved the updated
    key to the LRU end, making the node's own hot session the next eviction
    victim right after its context replicated back."""
    pool = SessionCachePool(capacity=2)
    pool.put("other", CacheEntry([3, 4], []))
    pool.put("hot", CacheEntry([1, 2], []))       # MRU
    # replication-arrival prime extends the hot serve entry off the hot path
    pool.put("hot", CacheEntry([1, 2, 5], []), low_priority=True)
    pool.put("new", CacheEntry([7, 8], []))       # evicts LRU
    assert "hot" in pool and "other" not in pool  # hot entry kept its rank
    # a normal (serving) put still promotes to MRU
    pool.put("new", CacheEntry([7, 8, 9], []))
    pool.put("x", CacheEntry([5], []))
    assert "new" in pool and "hot" not in pool


def test_prime_extension_preserves_serve_provenance_in_pool():
    """Regression companion to the Timing-counter tests above, at the pool
    level: extending a "serve" entry via a low-priority put keeps whatever
    source the caller passes — the prime paths pass the original."""
    pool = SessionCachePool(capacity=2)
    pool.put("s", CacheEntry([1, 2], [], source="serve"))
    pool.put("s", CacheEntry([1, 2, 3], [], source="serve"), low_priority=True)
    assert pool.peek("s").source == "serve"
    assert pool.peek("s").pos == 3


def test_low_priority_prime_never_evicts_serve_entries():
    """A prime for a session that only *might* roam here is inserted at the
    LRU end: on a full pool it is the immediate victim and the node's hot
    serve entries stay intact."""
    pool = SessionCachePool(capacity=2)
    pool.put("s1", CacheEntry([1, 2], []))
    pool.put("s2", CacheEntry([3, 4], []))
    pool.put("p", CacheEntry([5, 6], [], source="prime"), low_priority=True)
    assert "s1" in pool and "s2" in pool and "p" not in pool
    # with free capacity the prime survives, at LRU position
    pool2 = SessionCachePool(capacity=2)
    pool2.put("p", CacheEntry([5, 6], [], source="prime"), low_priority=True)
    pool2.put("s1", CacheEntry([1, 2], []))
    assert "p" in pool2
    pool2.put("s2", CacheEntry([3, 4], []))  # evicts the unused prime first
    assert "p" not in pool2 and "s1" in pool2 and "s2" in pool2


# ---------------------------------------------------------------------------
# Real-engine equivalence (slow: jit compiles)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def jax_cfg():
    return ModelConfig(
        name="mig-mini", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=4096, param_dtype="float32",
        compute_dtype="float32",
    )


@pytest.mark.slow
def test_jax_roam_warm_equals_cold_greedy(jax_cfg):
    """Per-node engines (same seed): the warm roam turn must produce exactly
    the cold path's greedy tokens while prefilling only the prompt suffix."""
    def build(warm):
        return EdgeCluster.build(
            ["a", "b"],
            lambda nid: JaxLLMService.create("mig-mini", jax_cfg, max_len=512),
            warm_start=warm,
        )

    outs = {}
    for warm in ("eager", "off"):
        cluster = build(warm)
        client = LLMClient(cluster, model="mig-mini",
                           mode=ContextMode.TOKENIZED, max_new_tokens=8)
        texts = []
        for i, node in enumerate(["a", "a", "b"]):
            r = client.chat(f"question {i} about robots", node)
            assert r.error is None, r.error
            texts.append(r.text)
            client.think(400)
        outs[warm] = texts
        t3 = client.response_log[2].timing
        if warm == "eager":
            assert t3.migrated and t3.kv_cache_hit and t3.kv_warm_start
            assert t3.prefill_tokens == client.response_log[2].n_prompt_tokens
            assert cluster.node("b").warm_starts >= 1
        else:
            assert t3.migrated and not t3.kv_cache_hit
    assert outs["eager"] == outs["off"]


@pytest.mark.slow
def test_engine_prime_then_generate_suffix_only(jax_cfg):
    """InferenceEngine.prime directly: a primed context makes the next
    generate a warm hit; a diverging prime is dropped safely."""
    svc = JaxLLMService.create("mig-mini", jax_cfg, max_len=512)
    tok = svc.tokenizer
    ctx = tok.encode("a replicated conversation about wheel odometry")
    assert svc.prime("k", ctx)
    assert svc.engine.session_pool.primes == 1
    assert svc.prime("k", ctx)                        # already warm: no-op
    assert svc.engine.session_pool.primes == 1

    p = tok.encode("next question")
    r = svc.completion(ctx, p, 8, cache_key="k")
    assert r.cache_hit and r.warm_start
    assert r.reused_tokens == len(ctx) and r.prefill_tokens == len(p)

    scratch = JaxLLMService.create("mig-mini", jax_cfg, max_len=512, kv_reuse=False)
    assert r.token_ids == scratch.completion(ctx, p, 8).token_ids

    # served turns overwrite provenance: the next hit is not a warm start
    r2 = svc.completion(ctx + p + r.token_ids, tok.encode("more"), 8, cache_key="k")
    assert r2.cache_hit and not r2.warm_start

    # divergent prime: drop + full reprime, still correct
    edited = list(ctx)
    edited[1] = (edited[1] + 1) % jax_cfg.vocab_size
    assert svc.prime("k", edited)
    r3 = svc.completion(edited, p, 8, cache_key="k")
    assert r3.cache_hit and r3.warm_start and r3.reused_tokens == len(edited)


@pytest.mark.slow
def test_prime_extension_of_serve_entry_not_warm(jax_cfg):
    """Regression (Timing counters): a turn served here leaves a "serve"
    entry; when its own context replicates back extended, the prime
    delta-extends it but must keep the provenance — the next local hit is
    NOT a migration warm start."""
    svc = JaxLLMService.create("mig-mini", jax_cfg, max_len=512)
    tok = svc.tokenizer
    p1 = tok.encode("first question about robots")
    r1 = svc.completion([], p1, 8, cache_key="s")
    assert svc.engine.session_pool.peek("s").source == "serve"

    # replication echoes the served history back, extended with a peer turn
    ctx = p1 + r1.token_ids
    extended = ctx + tok.encode("a turn appended elsewhere")
    assert svc.prime("s", extended)
    assert svc.engine.session_pool.peek("s").source == "serve"

    r2 = svc.completion(extended, tok.encode("next"), 8, cache_key="s")
    assert r2.cache_hit and r2.reused_tokens == len(extended)
    assert not r2.warm_start  # would have been True before the fix


@pytest.mark.slow
def test_prime_rejects_overlong_context(jax_cfg):
    svc = JaxLLMService.create("mig-mini", jax_cfg, max_len=64)
    assert not svc.prime("k", list(range(64)))
    assert "k" not in svc.engine.session_pool


# ---------------------------------------------------------------------------
# BatchedServer + session pool (slow: jit compiles)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_batched_server_pool_equivalence(jax_cfg):
    """Pool-backed slots must emit exactly the tokens of a pool-less server
    while reusing the previous turn's KV prefix on admission."""
    params = init_params(jax.random.key(0), jax_cfg)
    tok = get_tokenizer(jax_cfg.vocab_size, seed=0)
    ids1 = tok.encode("first turn about robots and sensors")

    plain = BatchedServer(jax_cfg, params, n_slots=2, max_len=128)
    plain.submit(ids1, max_new=6)
    ref1 = plain.run_to_completion()[0].token_ids

    pool = SessionCachePool(capacity=2)
    srv = BatchedServer(jax_cfg, params, n_slots=2, max_len=128, session_pool=pool)
    srv.submit(ids1, max_new=6, cache_key="s")
    f1 = srv.run_to_completion()[0]
    assert f1.token_ids == ref1 and not f1.cache_hit
    assert "s" in pool

    ids2 = ids1 + f1.token_ids + tok.encode("second turn about mapping")
    plain2 = BatchedServer(jax_cfg, params, n_slots=2, max_len=128)
    plain2.submit(ids2, max_new=6)
    ref2 = plain2.run_to_completion()[0].token_ids

    srv.finished.clear()
    srv.submit(ids2, max_new=6, cache_key="s")
    f2 = srv.run_to_completion()[0]
    assert f2.token_ids == ref2
    assert f2.cache_hit and f2.reused_tokens == len(ids1) + len(f1.token_ids)


@pytest.mark.slow
def test_batched_server_warm_start_from_primed_entry(jax_cfg):
    """A context primed by the migration hook speeds up the batched path:
    admission reuses the primed prefix (the engine and scheduler share one
    pool on a node)."""
    svc = JaxLLMService.create("mig-mini", jax_cfg, max_len=128)
    pool = svc.engine.session_pool
    tok = svc.tokenizer
    ctx = tok.encode("context replicated from a peer node")
    assert svc.prime("roamer", ctx)

    srv = BatchedServer(jax_cfg, svc.engine.params, n_slots=2, max_len=128,
                        session_pool=pool)
    suffix = tok.encode("fresh prompt")
    rid = srv.submit(ctx + suffix, max_new=6, cache_key="roamer")
    fin = {f.request_id: f for f in srv.run_to_completion()}
    assert fin[rid].cache_hit and fin[rid].reused_tokens == len(ctx)

    plain = BatchedServer(jax_cfg, svc.engine.params, n_slots=2, max_len=128)
    plain.submit(ctx + suffix, max_new=6)
    assert fin[rid].token_ids == plain.run_to_completion()[0].token_ids
