"""Block-granular paged session KV (docs/architecture.md, "Paged session
KV"): allocator mechanics and page accounting, greedy equivalence of the
paged batched server against the full-width path, page-budgeted pool
eviction / tenant capacity, and the slot-overflow + decode run-off
regressions. Hypothesis property tests cover the SessionCachePool stats
invariants and the allocator's free-list/refcount accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelConfig,
    decode_step,
    decode_step_paged,
    init_params,
    prefill,
)
from repro.serving import (
    BatchedServer,
    CacheEntry,
    PagedKVAllocator,
    SessionCachePool,
)
from repro.serving.paged_kv import SCRATCH_PAGE
from repro.tokenizer import get_tokenizer


@pytest.fixture(scope="module")
def cfg():
    return ModelConfig(
        name="tiny-paged", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=4096, param_dtype="float32",
        compute_dtype="float32",
    )


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.key(0), cfg)


@pytest.fixture(scope="module")
def tok(cfg):
    return get_tokenizer(cfg.vocab_size, seed=0)


# ---------------------------------------------------------------------------
# Allocator mechanics
# ---------------------------------------------------------------------------

def test_alloc_refcount_free(cfg):
    alloc = PagedKVAllocator(cfg, page_size=4, n_pages=8)
    assert alloc.n_free == 7                   # page 0 reserved as scratch
    a = alloc.alloc(3)
    assert len(a) == 3 and SCRATCH_PAGE not in a and len(set(a)) == 3
    assert alloc.used_pages == 3
    alloc.incref(a[:1])                        # a[0] now shared (ref 2)
    alloc.decref(a)
    assert alloc.used_pages == 1 and alloc.refcount(a[0]) == 1
    alloc.decref(a[:1])
    assert alloc.used_pages == 0 and alloc.n_free == 7
    assert alloc.alloc(8) is None              # over budget: None, no change
    assert alloc.n_free == 7
    assert alloc.resident_kv_bytes == 0
    assert alloc.total_kv_bytes == 7 * alloc.page_bytes


def test_pages_for(cfg):
    alloc = PagedKVAllocator(cfg, page_size=4, n_pages=4)
    assert alloc.pages_for(1) == 1 and alloc.pages_for(4) == 1
    assert alloc.pages_for(5) == 2 and alloc.pages_for(0) == 1


def test_store_gather_roundtrip(cfg, params):
    """dense -> pages -> dense must be bit-exact on every valid slot and
    mask everything beyond n_valid (including sub-page trims)."""
    max_len = 64
    ids = (np.arange(23)[None] * 7 % cfg.vocab_size).astype(np.int32)
    _, dense, _ = prefill(params, cfg, jnp.asarray(ids), max_len=max_len)
    alloc = PagedKVAllocator(cfg, page_size=16, n_pages=8)
    pages = alloc.store(dense, 23)
    assert len(pages) == 2 and alloc.used_pages == 2
    back = alloc.gather(pages, 23, max_len)
    valid = back[0]["kv_pos"] >= 0
    assert int(valid.sum()) == 23
    vm = valid[None, :, :, None, None]
    assert jnp.array_equal(
        jnp.where(vm, back[0]["k"], 0), jnp.where(vm, dense[0]["k"], 0)
    )
    assert jnp.array_equal(
        jnp.where(vm, back[0]["v"], 0), jnp.where(vm, dense[0]["v"], 0)
    )
    trimmed = alloc.gather(pages, 10, max_len)   # retry/resend trim view
    assert int((trimmed[0]["kv_pos"] >= 0).sum()) == 10


@pytest.mark.slow
def test_decode_step_paged_matches_dense(cfg, params):
    """The model-layer tentpole: paged decode (scatter into page cells +
    gather through the table) is exactly the full-width decode."""
    max_len = 64
    n = 37
    ids = (np.arange(n)[None] * 11 % cfg.vocab_size).astype(np.int32)
    logits, dense, pos = prefill(params, cfg, jnp.asarray(ids), max_len=max_len)

    alloc = PagedKVAllocator(cfg, page_size=16, n_pages=8)
    pages = alloc.store(dense, n)              # 3 pages cover pos < 48
    gathered = alloc.gather(pages, n, max_len)
    kv_pos = gathered[0]["kv_pos"]
    pools = alloc.pools
    table = jnp.asarray(alloc.table_for(pages, max_len))[None, :]

    tok_i = jnp.argmax(logits, -1).astype(jnp.int32)
    caches, pos_d = dense, pos
    tok_d = tok_p = tok_i
    pos_p = pos
    for _ in range(10):
        ld, caches = decode_step(params, cfg, caches, tok_d[:, None], pos_d)
        lp, pools, kv_pos = decode_step_paged(
            params, cfg, pools, table, kv_pos, tok_p[:, None], pos_p
        )
        assert jnp.array_equal(ld, lp)
        pos_d, pos_p = pos_d + 1, pos_p + 1
        tok_d = jnp.argmax(ld[:, 0], -1).astype(jnp.int32)
        tok_p = jnp.argmax(lp[:, 0], -1).astype(jnp.int32)
        assert jnp.array_equal(tok_d, tok_p)


def test_paged_write_step_drops_at_capacity(cfg, params):
    """Regression: a lane whose position reaches table capacity (exactly
    mp * page_size tokens) used to have its write *clamped* into the last
    page — silently overwriting the resident K/V of the token actually
    stored in that cell. The out-of-range write must be dropped instead."""
    from repro.models.cache import paged_write_step

    ps, mp, n_pages = 4, 3, 8
    kv, dh = cfg.n_kv_heads, cfg.d_head
    key = jax.random.key(1)
    pool_k = jax.random.normal(key, (n_pages, ps, kv, dh))
    pool_v = pool_k + 1.0
    table = jnp.asarray([[1, 2, 3]], jnp.int32)            # full lane: 3 pages
    k_new = jnp.ones((1, 1, kv, dh))
    v_new = jnp.ones((1, 1, kv, dh))

    # control: the last in-range position lands in the last page's tail cell
    pos = jnp.asarray([mp * ps - 1], jnp.int32)
    pk, pv = paged_write_step(pool_k, pool_v, k_new, v_new, pos, table, ps)
    assert jnp.array_equal(pk[3, ps - 1], k_new[0, 0])

    # at capacity: the write is dropped, resident KV is untouched
    pos = jnp.asarray([mp * ps], jnp.int32)
    pk, pv = paged_write_step(pool_k, pool_v, k_new, v_new, pos, table, ps)
    assert jnp.array_equal(pk, pool_k) and jnp.array_equal(pv, pool_v)


def test_decode_step_paged_kv_pos_drops_at_capacity(cfg, params):
    """The position table must drop the at-capacity update too: relabeling
    the last slot with the overflow position would mark a stale K/V cell
    causal for the current query."""
    width = 8
    kv_pos = jnp.asarray([[0, 1, 2, 3, 4, 5, 6, 7]], jnp.int32)
    from repro.serving import PagedKVAllocator

    alloc = PagedKVAllocator(cfg, page_size=4, n_pages=4)
    pages = alloc.alloc(2)
    table = jnp.asarray(alloc.table_for(pages, width))[None, :]
    tok = jnp.zeros((1, 1), jnp.int32)
    _, _, new_kv_pos = decode_step_paged(
        params, cfg, alloc.pools, table, kv_pos, tok, jnp.asarray([width], jnp.int32)
    )
    assert jnp.array_equal(new_kv_pos, kv_pos)


# ---------------------------------------------------------------------------
# Pool page accounting (deterministic; the pool is the sole allocator client)
# ---------------------------------------------------------------------------

def test_pool_page_accounting(cfg, params):
    # share_prefixes off: this test pins down the *unshared* accounting
    # identity (every entry page is a distinct physical page); the
    # cross-session dedup accounting has its own test below
    max_len = 64
    ids = (np.arange(40)[None] % cfg.vocab_size).astype(np.int32)
    _, dense, _ = prefill(params, cfg, jnp.asarray(ids), max_len=max_len)
    alloc = PagedKVAllocator(cfg, page_size=16, n_pages=9, share_prefixes=False)
    pool = SessionCachePool(capacity=8, allocator=alloc)

    pool.put("a", CacheEntry(list(range(40)), caches=dense))      # 3 pages
    pool.put("b", CacheEntry(list(range(20)), caches=dense))      # 2 pages
    assert pool.peek("a").paged and pool.peek("a").caches is None
    assert pool.pages_in_use == 5 == alloc.used_pages

    # divergent match invalidates and frees the entry's pages
    entry, usable = pool.match("b", [99, 98])
    assert entry is None and usable == 0
    assert pool.pages_in_use == 3 == alloc.used_pages

    # page-budgeted insert: needs 3 pages, only 5 free at capacity 8 is
    # fine; then a put that cannot fit reclaims the LRU entry
    pool.put("c", CacheEntry(list(range(33)), caches=dense))      # 3 pages
    assert alloc.used_pages == 6
    pool.put("d", CacheEntry(list(range(48)), caches=dense))      # 3 pages
    assert "a" not in pool and pool.evictions >= 1                # LRU evicted
    assert pool.pages_in_use == alloc.used_pages

    # low-priority puts never reclaim: fill the pool, then prime-insert
    free = alloc.n_free
    big = CacheEntry(list(range(free * 16 + 1)), caches=dense)
    pool.put("p", big, low_priority=True)
    assert "p" not in pool and pool.rejects == 1
    assert pool.pages_in_use == alloc.used_pages

    pool.clear()
    assert alloc.used_pages == 0 and pool.pages_in_use == 0


def test_pool_page_accounting_shared(cfg, params):
    """Cross-session dedup accounting: entries with a common token prefix
    share physical pages — logical pages_in_use exceeds used_pages by the
    dedup, unique_pages equals the physical count, and releasing one sharer
    keeps the page alive for the other."""
    max_len = 64
    ids = (np.arange(40)[None] % cfg.vocab_size).astype(np.int32)
    _, dense, _ = prefill(params, cfg, jnp.asarray(ids), max_len=max_len)
    alloc = PagedKVAllocator(cfg, page_size=16, n_pages=9)
    pool = SessionCachePool(capacity=8, allocator=alloc)

    pool.put("a", CacheEntry(list(range(40)), caches=dense))   # 3 pages
    pool.put("b", CacheEntry(list(range(20)), caches=dense))   # 2, first shared
    assert pool.pages_in_use == 5           # logical: each entry's own view
    assert alloc.used_pages == 4            # physical: page 0 deduped
    s = pool.stats()
    assert s["unique_pages"] == 4
    shared_page = pool.peek("a").pages[0]
    assert pool.peek("b").pages[0] == shared_page
    assert alloc.refcount(shared_page) == 2

    # donor eviction keeps the shared page alive for the sharer
    pool.invalidate("a")
    assert alloc.refcount(shared_page) == 1
    assert alloc.used_pages == 2 == pool.pages_in_use
    # ... and the index still names only live pages
    for pg in alloc.index.pages():
        assert alloc.refcount(pg) > 0
    pool.clear()
    assert alloc.used_pages == 0 and len(alloc.index) == 0


def test_cow_divergence_mid_page_isolated(cfg, params):
    """Copy-on-write isolation: two sessions sharing a full-page prefix and
    diverging MID-page must share exactly the full common pages and nothing
    else — each one's materialized bytes equal its own from-scratch prefill,
    so neither ever observes the other's writes."""
    max_len = 64
    ids_a = list(range(32)) + [500, 501, 502, 503, 504, 505, 506, 507]
    ids_b = list(range(32)) + [500, 501, 600, 601, 602, 603, 604, 605]
    # same first 2 pages, divergence at token 34 — inside page 2
    _, dense_a, _ = prefill(
        params, cfg, jnp.asarray(np.asarray(ids_a)[None], np.int32), max_len=max_len
    )
    _, dense_b, _ = prefill(
        params, cfg, jnp.asarray(np.asarray(ids_b)[None], np.int32), max_len=max_len
    )
    alloc = PagedKVAllocator(cfg, page_size=16, n_pages=9)
    pool = SessionCachePool(capacity=8, allocator=alloc)
    pool.put("a", CacheEntry(list(ids_a), caches=dense_a))
    pool.put("b", CacheEntry(list(ids_b), caches=dense_b))
    pa, pb = pool.peek("a").pages, pool.peek("b").pages
    assert pa[:2] == pb[:2]                  # full common pages: shared
    assert pa[2] != pb[2]                    # divergent page: fresh copy
    assert all(alloc.refcount(p) == 2 for p in pa[:2])
    for key, dense, n in (("a", dense_a, 40), ("b", dense_b, 40)):
        back = pool.materialize(pool.peek(key), n, max_len)
        valid = back[0]["kv_pos"] >= 0
        vm = valid[None, :, :, None, None]
        assert jnp.array_equal(
            jnp.where(vm, back[0]["k"], 0), jnp.where(vm, dense[0]["k"], 0)
        )
        assert jnp.array_equal(
            jnp.where(vm, back[0]["v"], 0), jnp.where(vm, dense[0]["v"], 0)
        )


@pytest.mark.slow
def test_cow_three_way_donor_eviction(cfg, params):
    """Donor eviction with live sharers: three sessions share the donor's
    prefix pages; evicting the donor must keep those pages resident (the
    sharers' refs pin them), keep the index mapping alive so LATER
    admissions still match, and keep every surviving entry's bytes exact."""
    max_len = 64
    base = list(range(32))
    mk = lambda suff: base + [700 + suff * 13 + i for i in range(6)]
    dense = {}
    for name, s in (("donor", 0), ("b", 1), ("c", 2), ("late", 3)):
        ids = mk(s)
        _, d, _ = prefill(
            params, cfg, jnp.asarray(np.asarray(ids)[None], np.int32),
            max_len=max_len,
        )
        dense[name] = (ids, d)
    alloc = PagedKVAllocator(cfg, page_size=16, n_pages=12)
    pool = SessionCachePool(capacity=8, allocator=alloc)
    for name in ("donor", "b", "c"):
        ids, d = dense[name]
        pool.put(name, CacheEntry(list(ids), caches=d))
    shared = pool.peek("donor").pages[:2]
    assert pool.peek("b").pages[:2] == shared == pool.peek("c").pages[:2]
    assert all(alloc.refcount(p) == 3 for p in shared)

    pool.invalidate("donor")                  # donor gone, sharers remain
    assert all(alloc.refcount(p) == 2 for p in shared)
    assert set(shared) <= set(alloc.index.pages())

    ids, d = dense["late"]                    # post-eviction admission still
    pool.put("late", CacheEntry(list(ids), caches=d))   # matches the run
    assert pool.peek("late").pages[:2] == shared
    assert all(alloc.refcount(p) == 3 for p in shared)
    for name in ("b", "c", "late"):
        ids, d = dense[name]
        back = pool.materialize(pool.peek(name), len(ids), max_len)
        valid = back[0]["kv_pos"] >= 0
        vm = valid[None, :, :, None, None]
        assert jnp.array_equal(
            jnp.where(vm, back[0]["k"], 0), jnp.where(vm, d[0]["k"], 0)
        )
    pool.clear()
    assert alloc.used_pages == 0 and len(alloc.index) == 0


def test_same_key_growth_reuses_own_pages(cfg, params):
    """Regression: replacing a key's own paged entry under page pressure
    frees the superseded pages first — a growing session must not evict
    every other tenant just to update itself."""
    max_len = 64
    ids = (np.arange(40)[None] % cfg.vocab_size).astype(np.int32)
    _, dense, _ = prefill(params, cfg, jnp.asarray(ids), max_len=max_len)
    alloc = PagedKVAllocator(cfg, page_size=16, n_pages=6)  # 5 allocatable
    pool = SessionCachePool(capacity=8, allocator=alloc)
    pool.put("a", CacheEntry(list(range(40)), caches=dense))  # 3 pages
    pool.put("b", CacheEntry(list(range(10)), caches=dense))  # 1 page
    # growing "a" to 4 pages: 1 free + its own 3 released >= 4 — "b" stays
    pool.put("a", CacheEntry(list(range(50)), caches=dense))
    assert "b" in pool and pool.peek("a").pos == 50
    assert pool.evictions == 0 and pool.rejects == 0
    assert alloc.used_pages == pool.pages_in_use == 5


# ---------------------------------------------------------------------------
# Server equivalence + page-moving reuse (shared servers: one compile set)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def servers(cfg, params):
    full = BatchedServer(
        cfg, params, n_slots=2, max_len=128,
        session_pool=SessionCachePool(capacity=4),
    )
    paged = BatchedServer(
        cfg, params, n_slots=2, max_len=128,
        session_pool=SessionCachePool(capacity=4),
        paged=True, page_size=16,
    )
    return full, paged


def _run(server, ids, key=None, max_new=6):
    rid = server.submit(ids, max_new=max_new, cache_key=key)
    fin = {f.request_id: f for f in server.run_to_completion()}
    return fin[rid]


def test_paged_server_greedy_equivalent(cfg, params, tok, servers):
    full, paged = servers
    reqs = [tok.encode(f"request {i} about robots and lidar") for i in range(5)]
    rids_f = [full.submit(r, max_new=6) for r in reqs]
    rids_p = [paged.submit(r, max_new=6) for r in reqs]
    fin_f = {f.request_id: f.token_ids for f in full.run_to_completion()}
    fin_p = {f.request_id: f.token_ids for f in paged.run_to_completion()}
    assert [fin_f[r] for r in rids_f] == [fin_p[r] for r in rids_p]
    # keyless requests release every page at finish
    assert paged.allocator.used_pages == 0


@pytest.mark.slow
def test_paged_session_reuse_matches_full_width(tok, servers):
    """Multi-turn sessions: write-back moves the slot's pages into the pool
    entry, and the next turn's admission shares them — token-for-token equal
    to the full-width pool path, same reuse accounting."""
    full, paged = servers
    ctx = []
    for turn in range(3):
        ids = ctx + tok.encode(f"turn {turn}: describe the sensor stack")
        f = _run(full, ids, key="sess-eq")
        p = _run(paged, ids, key="sess-eq")
        assert f.token_ids == p.token_ids
        assert f.reused_tokens == p.reused_tokens
        assert f.cache_hit == p.cache_hit == (turn > 0)
        ctx = ids + f.token_ids
    # the paged entry holds pages for its actual tokens, not max_len
    entry = paged.session_pool.peek("sess-eq")
    assert entry.paged
    assert len(entry.pages) == paged.allocator.pages_for(entry.pos)


def test_write_back_moves_pages_zero_copy(tok, servers):
    """After a keyed request finishes, the slot's pages ARE the pool
    entry's pages (refcount 1 — moved, not copied), and the next turn's
    admission shares the full prefix pages instead of reallocating them."""
    _, paged = servers
    f1 = _run(paged, tok.encode("a context that spans multiple pages " * 3),
              key="mv")
    entry = paged.session_pool.peek("mv")
    assert entry.paged and all(
        paged.allocator.refcount(p) == 1 for p in entry.pages
    )
    pages_before = list(entry.pages)
    n_full = entry.pos // paged.allocator.page_size  # fully-shared prefix pages
    f2 = _run(paged, entry.token_ids + tok.encode("next turn"), key="mv")
    assert f2.cache_hit and f2.reused_tokens == entry.pos
    entry2 = paged.session_pool.peek("mv")
    assert entry2.pages[:n_full] == pages_before[:n_full]  # moved, not copied
    assert all(paged.allocator.refcount(p) == 1 for p in entry2.pages)


def test_overlong_direct_submit_truncates(cfg, tok, servers):
    """Regression: a >max_len submission straight into BatchedServer.submit
    (bypassing the service shim) used to trip the _insert_slot assert and
    kill the node service. Both server modes must degrade by truncation —
    oldest tokens dropped, max_new capped — like the blocking shim."""
    for server in servers:
        huge = tok.encode("an endless rambling context " * 60)
        assert len(huge) > server.max_len
        f = _run(server, huge, key=None, max_new=8)
        assert 1 <= len(f.token_ids) <= 8


def test_decode_runoff_stops_cleanly(cfg, tok, servers):
    """A slot whose pos reaches cache width mid-decode must stop at the
    boundary (no silent mode="drop" KV loss) and leave a usable pool entry:
    the next turn of the session still admits and reuses (the strict-prefix
    resend below also exercises the paged tail-page swap path)."""
    for server in servers:
        filler = tok.encode("long session history " * 30)[: server.max_len - 10]
        f = _run(server, filler, key="runoff", max_new=500)
        # truncate_for_cache reserves at most 16 generation slots near the cap
        assert 1 <= len(f.token_ids) <= 16
        entry = server.session_pool.peek("runoff")
        assert entry is not None and entry.pos <= server.max_len
        f2 = _run(server, entry.token_ids[: server.max_len // 2], key="runoff",
                  max_new=4)
        assert f2.cache_hit and len(f2.token_ids) >= 1
        server.session_pool.invalidate("runoff")


def test_paged_prime_writes_pages(cfg, tok, servers):
    """BatchedServer.prime on the paged server lands the warm-start KV in
    pages (best-effort, low priority), and admission reuses it."""
    _, paged = servers
    ctx = tok.encode("replicated context from a keygroup peer")
    assert paged.prime("roam", ctx)
    entry = paged.session_pool.peek("roam")
    assert entry.paged and entry.source == "prime"
    f = _run(paged, ctx + tok.encode("fresh prompt"), key="roam")
    assert f.cache_hit and f.warm_start and f.reused_tokens == len(ctx)
    paged.session_pool.invalidate("roam")


def test_prime_already_covered_true_under_page_pressure(cfg, tok, servers):
    """Regression: a prime whose entry already covers the sequence is a
    no-op success even with zero free pages — the free-page guard must not
    run before the covers-everything check."""
    _, paged = servers
    ctx = tok.encode("already primed context")
    assert paged.prime("cover", ctx)
    held = paged.allocator.alloc(paged.allocator.n_free)  # exhaust the pool
    try:
        assert paged.allocator.n_free == 0
        assert paged.prime("cover", ctx)          # covered: still True
        assert not paged.prime("fresh-key", ctx)  # genuinely needs pages
    finally:
        paged.allocator.decref(held)
        paged.session_pool.invalidate("cover")


def test_concurrent_same_key_admissions_are_isolated(cfg, params, tok, servers):
    """Regression: two in-flight requests sharing a cache_key (client retry)
    must not share a live tail page — the tail-page swap at admission keeps
    slot KV isolated, so both decode exactly like the full-width server."""
    full, paged = servers
    ctx = tok.encode("session history for a duplicated retry")
    outs = {}
    for srv in (full, paged):
        _run(srv, ctx, key="dup", max_new=6)
        base = srv.session_pool.peek("dup").token_ids
        ids = base + tok.encode("the retried question")
        r1 = srv.submit(ids, max_new=6, cache_key="dup")
        r2 = srv.submit(ids, max_new=6, cache_key="dup")
        fin = {f.request_id: f for f in srv.run_to_completion()}
        srv.finished.clear()
        outs[srv.paged] = (fin[r1].token_ids, fin[r2].token_ids)
        srv.session_pool.invalidate("dup")
    assert outs[False] == outs[True]
    assert outs[True][0] == outs[True][1]


@pytest.mark.slow
def test_full_width_server_shares_paged_engine_pool(cfg, tok):
    """Mixed topology: a paged single-stream engine and a full-width
    batched server share one node pool. The server must materialize paged
    entries on admission (not assume entry.caches), and its dense
    write-back is re-paged by the pool."""
    from repro.serving import JaxLLMService

    svc = JaxLLMService.create(
        "tiny-paged", cfg, max_len=128, page_size=16, kv_pages=33
    )
    pool = svc.engine.session_pool
    ctx = tok.encode("context replicated from a peer node")
    assert svc.prime("mix", ctx)
    assert pool.peek("mix").paged

    srv = BatchedServer(cfg, svc.engine.params, n_slots=2, max_len=128,
                        session_pool=pool)  # full-width server, paged pool
    f = _run(srv, ctx + tok.encode("fresh prompt"), key="mix")
    assert f.cache_hit and f.warm_start and f.reused_tokens == len(ctx)
    entry = pool.peek("mix")
    assert entry.paged and entry.pos > len(ctx)  # write-back re-paged


@pytest.mark.slow
def test_tight_budget_session_recovers_by_evicting_donor(cfg, params, tok):
    """Regression: when the only reclaimable pages belong to the request's
    own reuse-donor entry (excluded from normal reclaim), admission must
    evict the donor and admit cold instead of raising 'pool too small' and
    killing the node service."""
    srv = BatchedServer(
        cfg, params, n_slots=1, max_len=64,
        session_pool=SessionCachePool(capacity=4),
        paged=True, page_size=16, kv_pages=1 + 3,
    )
    ids = tok.encode("a session that nearly fills the page pool")[:20]
    f1 = _run(srv, ids, key="big", max_new=8)
    entry = srv.session_pool.peek("big")
    assert entry is not None
    ids2 = entry.token_ids + tok.encode("more and more context words here")
    f2 = _run(srv, ids2, key="big", max_new=8)   # raised before the fix
    assert len(f2.token_ids) >= 1


def test_echo_prime_shorter_prefix_is_noop():
    """Regression (Echo twin parity): re-delivering an older, shorter
    context version must not truncate the held prefix or relabel its
    provenance — same as prime_session_pool's covers-everything no-op."""
    from repro.edge import EchoLLMService

    svc = EchoLLMService(model="m", vocab_size=1000, kv_reuse=True)
    p = [1, 2, 3, 4]
    r = svc.completion([], p, 8, cache_key="k")    # serve: holds p + gen
    held = svc._kv_prefix["k"]
    assert svc._kv_source["k"] == "serve"
    assert svc.prime("k", held[:3])                # stale shorter re-delivery
    assert svc._kv_prefix["k"] == held             # not truncated
    assert svc._kv_source["k"] == "serve"          # not relabeled


@pytest.mark.slow
def test_pool_exhaustion_mid_decode_degrades_gracefully(cfg, params, tok):
    """A page-multiple prompt admitted into a pool with no growth headroom
    still generates at least one token (admission covers pos n), and a slot
    that cannot grow mid-decode retires cleanly instead of crashing."""
    srv = BatchedServer(
        cfg, params, n_slots=1, max_len=64,
        session_pool=SessionCachePool(capacity=2),
        paged=True, page_size=16, kv_pages=1 + 3,
    )
    ids = [(i * 13) % cfg.vocab_size for i in range(32)]  # exactly 2 pages
    rid = srv.submit(ids, max_new=40, cache_key=None)
    fin = {f.request_id: f for f in srv.run_to_completion()}
    # pages cover 48 positions; decode stops at the boundary with the
    # 16 tokens that fit — never zero, never an exception
    assert 1 <= len(fin[rid].token_ids) <= 16
    assert srv.allocator.used_pages == 0  # everything released


# ---------------------------------------------------------------------------
# Tenant capacity: ≥2x sessions resident within the same KV budget
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_paged_doubles_resident_sessions_in_same_budget(cfg, params, tok):
    """Budget = 2 full-width lanes of KV bytes. A full-width pool fits 2
    session entries in that budget; the paged pool keeps all 4 tenants'
    actual KV resident in the same bytes, so every tenant's second turn is
    a pool hit while the full-width pool thrashes."""
    max_len, n_tenants = 128, 4
    lane_pages = max_len // 16
    paged = BatchedServer(
        cfg, params, n_slots=2, max_len=max_len,
        session_pool=SessionCachePool(capacity=8),
        paged=True, page_size=16, kv_pages=1 + 2 * lane_pages,
    )
    full = BatchedServer(
        cfg, params, n_slots=2, max_len=max_len,
        session_pool=SessionCachePool(capacity=2),   # same byte budget
    )
    lane_bytes = full._cache_bytes(full.caches) // full.n_slots
    assert paged.allocator.total_kv_bytes == 2 * lane_bytes

    base = {i: tok.encode(f"tenant {i} context about robots") for i in range(n_tenants)}
    hist = {}
    for i in range(n_tenants):
        f = _run(paged, base[i], key=f"t{i}", max_new=4)
        hist[i] = base[i] + f.token_ids
        g = _run(full, base[i], key=f"t{i}", max_new=4)
        assert g.token_ids == f.token_ids  # same budget, same outputs
    follow = {i: hist[i] + tok.encode("next") for i in range(n_tenants)}
    paged_hits = sum(
        _run(paged, follow[i], key=f"t{i}", max_new=4).cache_hit
        for i in range(n_tenants)
    )
    full_hits = sum(
        _run(full, follow[i], key=f"t{i}", max_new=4).cache_hit
        for i in range(n_tenants)
    )
    assert paged_hits == n_tenants        # >= 2x tenants warm per budget
    assert full_hits <= n_tenants // 2    # entry-counted LRU thrashes
    assert len(paged.session_pool) == n_tenants
    assert paged.allocator.resident_kv_bytes <= paged.allocator.total_kv_bytes
    assert len(full.session_pool) <= 2


# ---------------------------------------------------------------------------
# Fused paged-attention kernel on the serving path
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_pallas_paged_server_greedy_equivalent(cfg, params, tok):
    """End-to-end equivalence of the decode inner loop's two executions:
    a paged BatchedServer with ``attn_impl="pallas"`` (fused kernel
    attending through the page table, interpret mode on CPU) must emit
    greedy tokens identical to the paged gather-reference server —
    including multi-turn page reuse, where admission increfs shared prefix
    pages and the kernel reads them in place."""
    servers = {}
    for impl in ("reference", "pallas"):
        servers[impl] = BatchedServer(
            cfg.replace(attn_impl=impl), params, n_slots=2, max_len=128,
            session_pool=SessionCachePool(capacity=4),
            paged=True, page_size=16,
        )
    reqs = [tok.encode(f"request {i} about the lidar rig") for i in range(3)]
    outs = {}
    for impl, srv in servers.items():
        rids = [srv.submit(r, max_new=6) for r in reqs]
        fin = {f.request_id: f.token_ids for f in srv.run_to_completion()}
        outs[impl] = [fin[r] for r in rids]
        srv.finished.clear()
    assert outs["reference"] == outs["pallas"]

    ctx = []
    for turn in range(2):
        ids = ctx + tok.encode(f"turn {turn}: what changed?")
        fins = {impl: _run(srv, ids, key="kq") for impl, srv in servers.items()}
        assert fins["reference"].token_ids == fins["pallas"].token_ids
        assert fins["reference"].reused_tokens == fins["pallas"].reused_tokens
        assert fins["reference"].cache_hit == fins["pallas"].cache_hit == (turn > 0)
        ctx = ids + fins["reference"].token_ids


@pytest.mark.slow
def test_cross_session_sharing_token_identical(cfg, params, tok):
    """Tentpole e2e equivalence: N tenants with an identical multi-page
    system prompt, served with sharing on (reference + pallas cascade) and
    sharing off — greedy outputs token-identical everywhere, while the
    sharing servers hold strictly fewer physical pages and record the
    cross-session hits."""
    base = tok.encode("system: you are a helpful edge assistant. " * 6)
    assert len(base) >= 48                      # spans >= 3 full 16-pages
    reqs = [
        base + tok.encode(f"tenant {i}: what do you see?") for i in range(4)
    ]
    variants = {
        "ref_on": ("reference", True),
        "ref_off": ("reference", False),
        "pallas_on": ("pallas", True),
    }
    outs, srvs = {}, {}
    for name, (impl, share) in variants.items():
        srv = BatchedServer(
            cfg.replace(attn_impl=impl), params, n_slots=2, max_len=128,
            session_pool=SessionCachePool(capacity=8),
            paged=True, page_size=16, share_prefixes=share,
        )
        rids = [
            srv.submit(list(r), max_new=5, cache_key=f"t{i}")
            for i, r in enumerate(reqs)
        ]
        fin = {f.request_id: f.token_ids for f in srv.run_to_completion()}
        outs[name] = [fin[r] for r in rids]
        srvs[name] = srv
    assert outs["ref_on"] == outs["ref_off"] == outs["pallas_on"]

    on, off = srvs["ref_on"], srvs["ref_off"]
    # sharing dedups the common prompt pages: strictly fewer physical pages
    # resident for the same logical state, and the hits are accounted
    assert on.allocator.used_pages < off.allocator.used_pages
    s_on = on.session_pool.stats()
    assert s_on["shared_hits"] >= 3 and s_on["shared_tokens"] >= 3 * 48
    assert s_on["unique_pages"] < s_on["pages_in_use"]
    off_s = off.session_pool.stats()
    assert off_s["shared_hits"] == 0
    assert off_s["unique_pages"] == off_s["pages_in_use"]
    # invariants hold on every server: accounting balances, index only
    # names live pages
    for srv in srvs.values():
        alloc = srv.allocator
        assert alloc.used_pages + alloc.n_free == alloc.n_pages - 1
        for pg in alloc.index.pages():
            assert alloc.refcount(pg) > 0


# ---------------------------------------------------------------------------
# Full-width vs paged equivalence sweep under page pressure
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_full_width_vs_paged_equivalence_sweep(cfg, params, tok):
    """Interleaved multi-tenant sessions with a page budget tight enough to
    force reclaim: outputs must stay token-identical to the full-width
    server — reuse is a performance optimization, never a correctness
    dependency."""
    max_len = 128
    full = BatchedServer(
        cfg, params, n_slots=4, max_len=max_len,
        session_pool=SessionCachePool(capacity=16),
    )
    paged = BatchedServer(
        cfg, params, n_slots=4, max_len=max_len,
        session_pool=SessionCachePool(capacity=16),
        paged=True, page_size=16, kv_pages=1 + 3 * (max_len // 16),
    )
    sessions = {i: tok.encode(f"tenant {i} opening question") for i in range(6)}
    for rnd in range(3):
        rids_f = {
            i: full.submit(list(ids), max_new=5, cache_key=f"s{i}")
            for i, ids in sessions.items()
        }
        rids_p = {
            i: paged.submit(list(ids), max_new=5, cache_key=f"s{i}")
            for i, ids in sessions.items()
        }
        fin_f = {f.request_id: f for f in full.run_to_completion()}
        fin_p = {f.request_id: f for f in paged.run_to_completion()}
        for i in sessions:
            tf, tp = fin_f[rids_f[i]].token_ids, fin_p[rids_p[i]].token_ids
            assert tf == tp, (rnd, i)
            sessions[i] = sessions[i] + tf + tok.encode(f"round {rnd} follow-up")
        full.finished.clear()
        paged.finished.clear()
    # page accounting stayed consistent under pressure
    alloc = paged.allocator
    assert alloc.used_pages == paged.session_pool.pages_in_use
    assert alloc.used_pages + alloc.n_free == alloc.n_pages - 1


