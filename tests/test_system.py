"""End-to-end system behaviour: the paper's full scenario on the real JAX
engine — 9-turn conversation, node switches at turns 3/5/7, all metrics."""

import pytest

pytestmark = pytest.mark.slow  # multi-minute: full 9-turn scenarios on the real engine

from repro.core import ContextMode
from repro.edge import EdgeCluster, LLMClient
from repro.models import ModelConfig
from repro.serving import JaxLLMService
from repro.store import Link

PROMPTS = [
    "What are the fundamental components of an autonomous mobile robot?",
    "You mentioned sensors. What are the most common types for obstacle avoidance?",
    "Can you explain the concept of a PID controller in the context of motor control?",
    "Write a simple Python function for a proportional controller.",
    "In your previous code, what do the kp and error variables represent?",
    "How would you modify that function to include the integral component?",
    "Now, let's talk about localization. What is SLAM?",
    "What are some of the main challenges when implementing that on a small robot?",
    "Can you compare the EKF SLAM and Particle Filter SLAM approaches?",
]
# paper Fig. 6: the client switches nodes on turns 3, 5 and 7
NODES = ["m2", "m2", "tx2", "tx2", "m2", "m2", "tx2", "tx2", "m2"]


@pytest.fixture(scope="module")
def shared_service():
    cfg = ModelConfig(
        name="paper-mini", arch_type="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=8192,
        qkv_bias=True, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
    )
    return JaxLLMService.create("paper-mini", cfg, max_len=2048)


def run_scenario(service, mode):
    cluster = EdgeCluster.build(
        ["m2", "tx2"],
        lambda nid: service,
        inter_node_link=Link(latency_ms=2.0, bandwidth_mbps=100.0),
        client_link=Link(latency_ms=5.0, bandwidth_mbps=20.0),
    )
    client = LLMClient(cluster, model="paper-mini", mode=mode, max_new_tokens=16)
    resps = []
    for p, n in zip(PROMPTS, NODES):
        r = client.chat(p, n)
        assert r.error is None, r.error
        resps.append(r)
        client.think(500)
    cluster.converge()
    return cluster, client, resps


def test_nine_turn_scenario_tokenized(shared_service):
    cluster, client, resps = run_scenario(shared_service, ContextMode.TOKENIZED)
    assert [r.turn for r in resps] == list(range(1, 10))
    ctx = [r.n_context_tokens for r in resps]
    assert ctx == sorted(ctx) and ctx[0] == 0 and ctx[-1] > 100
    assert cluster.sync_bytes() > 0
    # constant-size requests (Fig. 7): no growth with history
    assert max(client.request_bytes_log) < 400


def test_nine_turn_scenario_consistency_across_switches(shared_service):
    """After each switch, the model's answer must still be conditioned on
    the full prior context — compare against a never-switching run."""
    _, _, roaming = run_scenario(shared_service, ContextMode.TOKENIZED)

    cluster = EdgeCluster.build(["m2", "tx2"], lambda nid: shared_service)
    stay = LLMClient(cluster, model="paper-mini", mode=ContextMode.TOKENIZED,
                     max_new_tokens=16)
    static = []
    for p in PROMPTS:
        r = stay.chat(p, "m2")
        static.append(r)
        stay.think(500)
    # identical greedy model + identical context => identical responses,
    # regardless of which node served the request
    assert [r.text for r in roaming] == [r.text for r in static]


def test_client_side_equivalence_first_turn(shared_service):
    """With identical (empty) context, mode must not change the generation.

    Later turns can diverge textually with a random-weights model because
    raw/client-side modes re-render the assistant reply from decoded text
    while tokenized mode stores the generated ids verbatim (a real trained
    model's output re-encodes canonically; random ids need not) — so exact
    multi-turn equality is only asserted turn 1; context-dependence is
    covered by test_nine_turn_scenario_consistency_across_switches."""
    _, _, edge = run_scenario(shared_service, ContextMode.TOKENIZED)
    _, _, cs = run_scenario(shared_service, ContextMode.CLIENT_SIDE)
    assert edge[0].text == cs[0].text
    # both modes keep growing conversation state
    assert cs[-1].n_prompt_tokens > cs[0].n_prompt_tokens


def test_raw_mode_tokenize_cost_dominates(shared_service):
    """Raw mode re-tokenizes the whole history each turn: its per-turn
    tokenize time must exceed tokenized mode's (which only encodes the new
    prompt) — the mechanical basis of the paper's Fig. 3."""
    _, _, tok = run_scenario(shared_service, ContextMode.TOKENIZED)
    _, _, raw = run_scenario(shared_service, ContextMode.RAW)
    assert tok[0].text == raw[0].text
    t_tok = sum(r.timing.tokenize_ms for r in tok[4:])
    t_raw = sum(r.timing.tokenize_ms for r in raw[4:])
    assert t_raw > t_tok
    # raw context grows (chars) and is re-tokenized into the prompt
    assert raw[-1].n_prompt_tokens > tok[-1].n_prompt_tokens * 0.5
