"""Cross-session shared-prefix index: unit tests for the chained content
hash (``page_digests``) and the weak ``PrefixPageIndex``, plus a seeded
random-walk state machine over interleaved admit / extend / evict / COW /
crash sequences against a real ``SessionCachePool`` + ``PagedKVAllocator``.

After every op the walk asserts the structural invariants the sharing
design rests on:

- free-list + refcount accounting balances (used + free == allocatable);
- no page is ever both free and referenced;
- the content index never maps a hash to a released page;
- the pool's entries account for every outstanding reference;
- every entry's gathered bytes equal a freshly computed lane for its
  token prefix — i.e. no sharer ever observes another session's writes
  (the copy-on-write guarantee), even across donor eviction and crashes.

The deterministic seeds always run; a hypothesis-driven seed sweep rides
along where the optional dependency is installed (see _hypothesis_support).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_support import given, settings, st

from repro.models import ModelConfig
from repro.serving import CacheEntry, PagedKVAllocator, SessionCachePool
from repro.serving.paged_kv import SCRATCH_PAGE, PrefixPageIndex, page_digests

PS = 4  # page size used throughout


# ---------------------------------------------------------------------------
# page_digests: chained content hash
# ---------------------------------------------------------------------------

def test_page_digests_counts_full_pages_only():
    ids = list(range(11))                      # 2 full pages + partial tail
    assert len(page_digests(ids, PS)) == 2
    assert page_digests(ids[:3], PS) == []     # sub-page prefix: nothing
    assert page_digests([], PS) == []
    assert len(page_digests(ids, PS, limit=1)) == 1
    assert len(page_digests(ids, PS, limit=0)) == 0
    assert len(page_digests(ids, PS, limit=99)) == 2


def test_page_digests_chained_commit():
    """Digest i commits to the ENTIRE prefix [0, (i+1)*ps), not block i
    alone: equal later blocks after an early divergence must NOT collide,
    while a shared head shares exactly its leading digests."""
    a = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]
    b = list(a)
    b[0] = 99                                   # diverge inside page 0
    da, db = page_digests(a, PS), page_digests(b, PS)
    assert all(x != y for x, y in zip(da, db))  # chain poisons every digest
    c = a[:8] + [77, 77, 77, 77]                # shared head, new page 2
    dc = page_digests(c, PS)
    assert dc[:2] == da[:2] and dc[2] != da[2]
    # determinism across calls
    assert page_digests(a, PS) == da


# ---------------------------------------------------------------------------
# PrefixPageIndex: weak digest -> page mapping
# ---------------------------------------------------------------------------

def test_prefix_index_run_and_first_writer_wins():
    idx = PrefixPageIndex()
    d = page_digests(list(range(16)), PS)       # 4 chained digests
    idx.register(d[0], 5)
    idx.register(d[1], 6)
    idx.register(d[3], 8)                       # gap at d[2]
    assert idx.lookup_run(d) == [5, 6]          # run stops at the gap
    assert idx.lookup_run(d[2:]) == []
    idx.register(d[0], 9)                       # duplicate digest: ignored
    idx.register(d[2], 6)                       # duplicate page: ignored
    assert idx.lookup_run(d) == [5, 6]
    assert len(idx) == 3 and sorted(idx.pages()) == [5, 6, 8]


def test_prefix_index_drop_page():
    idx = PrefixPageIndex()
    d = page_digests(list(range(8)), PS)
    idx.register(d[0], 3)
    idx.register(d[1], 4)
    idx.drop_page(3)
    assert idx.lookup_run(d) == []              # head gone => no run
    assert idx.lookup_run(d[1:]) == [4]         # deeper digests still live
    idx.drop_page(3)                            # idempotent
    idx.drop_page(999)                          # unknown page: no-op
    assert len(idx) == 1
    # dropped digest can be re-registered to a new page (recycled content)
    idx.register(d[0], 7)
    assert idx.lookup_run([d[0]]) == [7]


# ---------------------------------------------------------------------------
# Random-walk state machine: admit / extend / evict / COW / crash
# ---------------------------------------------------------------------------

_cfg = ModelConfig(
    name="micro-idx", arch_type="dense", n_layers=1, d_model=16, n_heads=2,
    n_kv_heads=1, d_ff=16, vocab_size=4096, param_dtype="float32",
    compute_dtype="float32",
)
WIDTH = 32  # dense lane width (slots); 8 pages of PS


def _lane(ids):
    """Synthetic dense B=1 KV lane whose value at slot j is a chained hash
    of tokens [0, j] — mirroring real KV, where position j depends on the
    full causal prefix. Exact in float32 (< 2**20), so byte-compare works."""
    dh = _cfg.d_model // _cfg.n_heads
    k = np.zeros((_cfg.n_layers, 1, WIDTH, _cfg.n_kv_heads, dh), np.float32)
    h = 0
    for j, t in enumerate(ids):
        h = (h * 8191 + int(t) + 1) % (1 << 20)
        k[:, 0, j] = float(h)
    return [{"k": jnp.asarray(k), "v": jnp.asarray(-k)}]


def _check_invariants(alloc, pool):
    free = alloc._free
    # 1. accounting balances, free list is duplicate-free, scratch reserved
    assert alloc.used_pages + alloc.n_free == alloc.n_pages - 1
    assert len(set(free)) == len(free) and SCRATCH_PAGE not in free
    assert alloc.refcount(SCRATCH_PAGE) == 0
    # 2. no page both free and referenced; every non-free page is referenced
    for p in range(1, alloc.n_pages):
        assert (alloc.refcount(p) > 0) == (p not in free), p
    # 3. the index never names a released page
    for p in alloc.index.pages():
        assert alloc.refcount(p) > 0, p
    # 4. pool entries account for every outstanding reference (the pool is
    #    the allocator's sole client in this walk)
    held = [p for e in pool._entries.values() if e.paged for p in e.pages]
    refs = {p: alloc.refcount(p) for p in range(1, alloc.n_pages)}
    assert sum(refs.values()) == len(held)
    for p in set(held):
        assert refs[p] == held.count(p), p


def _check_contents(alloc, pool, expected):
    """COW isolation: every entry's gathered bytes must equal a lane
    recomputed from ITS OWN token prefix — regardless of which physical
    pages it shares with whom, and of any donor eviction in between."""
    for key, entry in pool._entries.items():
        ids = expected[key]
        assert entry.token_ids == ids
        want = _lane(ids)[0]["k"][0, 0, : len(ids)]
        got = pool.materialize(entry, len(ids), WIDTH)
        assert int((got[0]["kv_pos"] >= 0).sum()) == len(ids)
        assert jnp.array_equal(got[0]["k"][0, 0, : len(ids)], want)
        assert jnp.array_equal(got[0]["v"][0, 0, : len(ids)], -want)


BASE = [7, 3, 11, 5, 2, 13, 17, 19]  # two full shared-prompt pages


def _walk(seed, n_ops=120, check_every=6):
    rng = np.random.default_rng(seed)
    alloc = PagedKVAllocator(_cfg, page_size=PS, n_pages=16)
    pool = SessionCachePool(capacity=4, allocator=alloc)
    keys = [f"s{i}" for i in range(5)]
    expected = {}

    def ids_for(key, n_extra):
        """Shared base prefix + per-key suffix: admissions collide on the
        base pages (cross-session sharing) then diverge mid-page (COW)."""
        n_base = int(rng.integers(2, len(BASE) + 1))
        suffix = [
            100 + keys.index(key) * 37 + i for i in range(n_extra)
        ]
        return BASE[:n_base] + suffix

    for step in range(n_ops):
        op = rng.choice(
            ["admit", "extend", "evict", "crash"], p=[0.45, 0.3, 0.2, 0.05]
        )
        key = keys[int(rng.integers(len(keys)))]
        if op == "admit":
            ids = ids_for(key, int(rng.integers(0, 9)))
            pool.put(key, CacheEntry(list(ids), _lane(ids)),
                     low_priority=bool(rng.integers(2)))
            if key in pool:
                expected[key] = ids
        elif op == "extend":
            cur = pool.peek(key)
            if cur is None:
                continue
            ids = list(cur.token_ids) + [
                200 + int(t) for t in rng.integers(0, 50, int(rng.integers(1, 5)))
            ]
            if len(ids) > WIDTH:
                continue
            pool.put(key, CacheEntry(list(ids), _lane(ids)))
            if key in pool:
                expected[key] = ids
        elif op == "evict":
            pool.invalidate(key)
        else:  # crash: node restart drops all resident state at once
            pool.clear()
        expected = {k: v for k, v in expected.items() if k in pool}
        _check_invariants(alloc, pool)
        if step % check_every == 0:
            _check_contents(alloc, pool, expected)
    _check_contents(alloc, pool, expected)
    # drain: releasing everything must return the allocator to pristine
    pool.clear()
    _check_invariants(alloc, pool)
    assert alloc.used_pages == 0 and alloc.n_free == alloc.n_pages - 1
    assert len(alloc.index) == 0


@pytest.mark.parametrize("seed", [0, 1, 7])
def test_shared_index_random_walk(seed):
    _walk(seed)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_shared_index_random_walk_property(seed):
    _walk(seed, n_ops=60, check_every=10)


def test_store_shares_then_releases_on_alloc_failure():
    """store() under page exhaustion: the protective shared increfs must be
    rolled back — a failed store leaves refcounts and the index exactly as
    they were (no page leaked, no phantom sharing)."""
    alloc = PagedKVAllocator(_cfg, page_size=PS, n_pages=5)  # 4 allocatable
    a = BASE[:8] + [101]
    pa = alloc.store(_lane(a), len(a), a)                    # 3 pages
    assert pa is not None and len(pa) == 3
    before = {p: alloc.refcount(p) for p in pa}
    b = BASE[:8] + [102, 103, 104, 105, 106]                 # needs 2 fresh
    assert alloc.store(_lane(b), len(b), b) is None          # only 1 free
    assert {p: alloc.refcount(p) for p in pa} == before
    assert alloc.n_free == 1 and len(alloc.index) == 2
