"""Chunked paged prefill (docs/architecture.md, "Chunked paged prefill"):
prompt tokens land straight in KV pages, split into page-aligned chunks.

Model-layer equivalence matrix: prefill_chunk_paged against the dense
prefill at every chunk-boundary shape (one page, two pages, ragged last
chunk, chunk == full prompt) x {reference, pallas} x shared-prefix
{off, on} — greedy-token identical everywhere. Server-level: the batched
scheduler's unified steps produce token-identical outputs across chunk
budgets (including None, the full-prefill stall baseline), FIFO-fair
admission never starves a small tenant behind an infeasible big one, and
the new ttft/decode-gap accounting is populated."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelConfig,
    init_params,
    layer_groups,
    prefill,
    prefill_chunk_paged,
)
from repro.models.cache import init_paged_pool
from repro.serving import BatchedServer, SessionCachePool

PS = 16    # page size used throughout
MP = 6     # table width (pages) for the model-layer matrix
N = 40     # prompt length: 2 full pages + a ragged half page


@pytest.fixture(
    scope="module",
    params=[
        pytest.param("reference"),
        pytest.param("pallas", marks=pytest.mark.slow),
    ],
)
def impl_cfg(request):
    return ModelConfig(
        name="tiny-chunk", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
        param_dtype="float32", compute_dtype="float32",
        attn_impl=request.param,
    )


@pytest.fixture(scope="module")
def impl_params(impl_cfg):
    return init_params(jax.random.PRNGKey(0), impl_cfg)


def _chunk_run(cfg, params, tokens, chunk, n_shared_pages=0, donor=None):
    """Prefill ``tokens`` through prefill_chunk_paged in ``chunk``-token
    steps against a fresh pool; returns the final logits (V,). With
    ``donor``, a first run writes the shared-prefix pages and the main run
    starts past them with n_skip (reads them, writes dropped)."""
    pools = [
        init_paged_pool(cfg, spec.n_blocks, 32, PS)
        for spec in layer_groups(cfg)
    ]
    table = jnp.asarray(np.arange(1, MP + 1, dtype=np.int32)[None, :])
    if donor is not None:
        _, pools = prefill_chunk_paged(
            params, cfg, pools, table,
            jnp.asarray(np.asarray(donor, np.int32)[None, :]),
            jnp.zeros((1,), jnp.int32),
            jnp.full((1,), len(donor), jnp.int32),
        )
    pos, logits = n_shared_pages * PS, None
    rest = list(tokens[n_shared_pages * PS:])
    while rest:
        c, rest = rest[:chunk], rest[chunk:]
        padded = np.zeros((chunk,), np.int32)
        padded[: len(c)] = c
        logits, pools = prefill_chunk_paged(
            params, cfg, pools, table, jnp.asarray(padded[None, :]),
            jnp.full((1,), pos, jnp.int32),
            jnp.full((1,), len(c), jnp.int32),
            n_skip=n_shared_pages,
        )
        pos += len(c)
    return np.asarray(logits[0])


@pytest.mark.parametrize(
    "chunk",
    [
        pytest.param(PS, marks=pytest.mark.slow, id="1page"),
        pytest.param(2 * PS, marks=pytest.mark.slow, id="2pages"),
        pytest.param(48, id="ragged"),
        pytest.param(N, marks=pytest.mark.slow, id="full"),
    ],
)
@pytest.mark.parametrize("shared", [False, True], ids=["cold", "sharedpfx"])
def test_chunk_boundaries_match_dense_prefill(impl_cfg, impl_params, chunk, shared):
    """Every chunk split — including a ragged last chunk and the
    degenerate one-chunk case — lands the same greedy token as the dense
    one-shot prefill, with and without leading read-only shared pages."""
    rng = np.random.default_rng(7)
    tokens = rng.integers(1, impl_cfg.vocab_size, size=N).astype(np.int32)
    ref_logits, _, _ = prefill(
        impl_params, impl_cfg, jnp.asarray(tokens[None, :]), max_len=MP * PS
    )
    ref = np.asarray(ref_logits[0])
    got = _chunk_run(
        impl_cfg, impl_params, tokens, chunk,
        n_shared_pages=2 if shared else 0,
        donor=tokens[:2 * PS] if shared else None,
    )
    assert int(ref.argmax()) == int(got.argmax())
    np.testing.assert_allclose(got, ref, atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# Server level: unified steps
# ---------------------------------------------------------------------------

def _serve(cfg, params, reqs, budget, stagger=0, max_new=6):
    """Run ``reqs`` through a paged BatchedServer with the given chunk
    budget; requests after the first are submitted ``stagger`` steps in.
    Returns ({rid: tokens}, server)."""
    srv = BatchedServer(
        cfg, params, n_slots=2, max_len=128,
        session_pool=SessionCachePool(capacity=8),
        paged=True, page_size=PS, prefill_chunk_tokens=budget,
    )
    rids = [srv.submit(list(reqs[0]), max_new=max_new, cache_key="s0")]
    for _ in range(stagger):
        srv.step()
    rids += [
        srv.submit(list(r), max_new=max_new, cache_key=f"s{i + 1}")
        for i, r in enumerate(reqs[1:])
    ]
    fin = {f.request_id: f.token_ids for f in srv.run_to_completion()}
    return [fin[r] for r in rids], srv


@pytest.mark.slow
def test_chunk_budgets_token_identical(tiny_dense_cfg):
    """The per-step chunk budget is a latency knob, not a model change:
    budgets 16 / 64 / None (stall baseline) generate identical greedy
    tokens for a resident tenant plus a long mid-flight admission."""
    cfg = tiny_dense_cfg
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    reqs = [
        rng.integers(1, cfg.vocab_size, size=20).tolist(),
        rng.integers(1, cfg.vocab_size, size=90).tolist(),
    ]
    outs = {
        b: _serve(cfg, params, reqs, b, stagger=2)[0]
        for b in (16, 64, None)
    }
    assert outs[16] == outs[64] == outs[None]
    for toks in outs[16]:
        assert len(toks) == 6


def test_latency_accounting_populated(tiny_dense_cfg):
    """FinishedRequest carries ttft and per-token decode gap percentiles;
    a later tenant's ttft includes its queue/chunk wait."""
    cfg = tiny_dense_cfg
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    reqs = [rng.integers(1, cfg.vocab_size, size=30).tolist() for _ in range(2)]
    srv = BatchedServer(
        cfg, params, n_slots=2, max_len=128,
        session_pool=SessionCachePool(capacity=4),
        paged=True, page_size=PS, prefill_chunk_tokens=16,
    )
    for i, r in enumerate(reqs):
        srv.submit(r, max_new=5, cache_key=f"k{i}")
    for f in srv.run_to_completion():
        assert f.ttft_ms > 0.0
        assert f.decode_p99_ms >= f.decode_p50_ms > 0.0


def test_fifo_fair_admission_no_starvation(tiny_dense_cfg):
    """Regression (two tenants, tight page budget): a big request the pool
    cannot cover yet must not block a small feasible one queued behind it
    — the small tenant admits into the free slot, the big one keeps its
    queue position and admits once pages free up."""
    cfg = tiny_dense_cfg
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    srv = BatchedServer(
        cfg, params, n_slots=2, max_len=128, session_pool=None,
        paged=True, page_size=PS, kv_pages=1 + 8, prefill_chunk_tokens=64,
    )
    r_res = srv.submit(rng.integers(1, 512, size=33).tolist(), max_new=40)
    r_big = srv.submit(rng.integers(1, 512, size=95).tolist(), max_new=4)
    r_small = srv.submit(rng.integers(1, 512, size=17).tolist(), max_new=4)
    # resident: 3 pages; big needs 6 of the remaining 5 -> skipped;
    # small needs 2 -> admitted into the second slot the same step
    srv.step()
    assert {s.request_id for s in srv.slots if s is not None} == {r_res, r_small}
    assert [q[0] for q in srv.queue] == [r_big]
    fin = {f.request_id: f.token_ids for f in srv.run_to_completion()}
    assert set(fin) == {r_res, r_big, r_small}
    assert all(len(t) >= 1 for t in fin.values())
    order = [f.request_id for f in srv.finished]
    assert order.index(r_small) < order.index(r_big)


@pytest.mark.slow
def test_interleave_sweep_budget_vs_stall(tiny_dense_cfg):
    """Interleave sweep across budgets and staggers: outputs stay
    token-identical, and under the budgeted servers the resident keeps
    emitting tokens *while* the long prompt is still mid-prefill (with
    None it cannot — the stall)."""
    cfg = tiny_dense_cfg
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(13)
    reqs = [
        rng.integers(1, cfg.vocab_size, size=16).tolist(),
        rng.integers(1, cfg.vocab_size, size=100).tolist(),
        rng.integers(1, cfg.vocab_size, size=50).tolist(),
    ]
    for stagger in (0, 3):
        outs = {
            b: _serve(cfg, params, reqs, b, stagger=stagger, max_new=8)[0]
            for b in (16, 32, 64, None)
        }
        vals = list(outs.values())
        assert all(v == vals[0] for v in vals[1:])
