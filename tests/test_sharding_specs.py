"""Unit tests for the sharding rules — every assigned arch gets a complete,
divisibility-correct PartitionSpec tree (these run on 1 device: specs are
pure metadata)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.models import abstract_params
from repro.models.pjit_rules import attention_weights_replicated, rules_for
from repro.launch.sharding import (
    batch_specs,
    fsdp_param_specs,
    opt_state_specs,
    param_specs,
)

MODEL = 16
ARCHS = sorted(ASSIGNED)


def _check_divisible(spec: P, shape, where=""):
    for axis_name, dim in zip(tuple(spec) + (None,) * (len(shape) - len(spec)), shape):
        if axis_name is None:
            continue
        names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
        total = 1
        for n in names:
            total *= {"pod": 2, "data": 16, "model": 16}[n]
        assert dim % total == 0, f"{where}: dim {dim} not divisible by {total}"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_complete_and_divisible(arch):
    cfg = get_config(arch)
    abs_p = abstract_params(cfg)
    specs = param_specs(cfg, abs_p, MODEL)
    leaves_p = jax.tree.leaves(abs_p)
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_p) == len(leaves_s)
    for leaf, spec in zip(leaves_p, leaves_s):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim
        _check_divisible(spec, leaf.shape, where=f"{arch}")


@pytest.mark.parametrize("arch", ["dbrx-132b", "nemotron-4-340b"])
def test_fsdp_never_shards_stack_dim(arch):
    """Regression for the 250 GB scan-accumulator bug (EXPERIMENTS §Perf B)."""
    cfg = get_config(arch)
    abs_p = abstract_params(cfg)
    specs = fsdp_param_specs(cfg, abs_p, MODEL)
    for (path, leaf), spec in zip(
        jax.tree_util.tree_flatten_with_path(abs_p)[0],
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
    ):
        keys = [getattr(p, "key", None) for p in path]
        if "groups" in str(keys) and leaf.ndim >= 3:
            parts = tuple(spec) + (None,) * (leaf.ndim - len(spec))
            assert parts[0] != "data", (keys, spec)


@pytest.mark.parametrize("arch", ARCHS)
def test_opt_specs_shard_more_than_params(arch):
    cfg = get_config(arch)
    abs_p = abstract_params(cfg)
    opt_abs = {
        "m": abs_p, "v": abs_p,
        "step": jax.ShapeDtypeStruct((), jax.numpy.int32),
    }
    ospecs = opt_state_specs(cfg, opt_abs, MODEL, zero1=True)
    n_data = sum(
        1 for s in jax.tree.leaves(ospecs["m"], is_leaf=lambda x: isinstance(x, P))
        if "data" in str(s)
    )
    assert n_data > 0  # ZeRO-1 actually bites


def test_attention_replication_rule():
    assert attention_weights_replicated(get_config("qwen2-0.5b"))      # 14 heads
    assert attention_weights_replicated(get_config("qwen2-vl-7b"))     # 28
    assert not attention_weights_replicated(get_config("gemma2-27b"))  # 32
    assert not attention_weights_replicated(get_config("nemotron-4-340b"))  # 96


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("kind", ["train", "decode"])
def test_rules_consistent(arch, kind):
    cfg = get_config(arch)
    rules = rules_for(cfg, multi_pod=True, kind=kind)
    assert rules["batch"] == ("pod", "data")
    if kind == "decode":
        assert rules["seq"] is None  # can't shard a length-1 query
    if cfg.n_heads and cfg.n_heads % 16 == 0:
        assert rules["heads"] == "model"


@pytest.mark.parametrize("arch", ["musicgen-medium", "qwen2-vl-7b", "qwen2-0.5b"])
def test_batch_specs_shapes(arch):
    cfg = get_config(arch)
    bs = batch_specs(cfg, multi_pod=False, kind="train")
    assert "tokens" in bs and "labels" in bs
    if cfg.n_patches:
        assert "patch_embeds" in bs
    want_rank = 3 if cfg.n_codebooks > 1 else 2
    assert len(bs["tokens"]) == want_rank
