"""Graceful degradation for hypothesis-based tests.

The property-test modules used to open with a module-level
``pytest.importorskip("hypothesis")``, which skipped the ENTIRE module —
including deterministic unit tests that never touch hypothesis — whenever
the optional dependency was missing. That masked real regressions behind
a single opaque "module skipped" line.

This shim keeps the dependency optional while letting deterministic tests
run everywhere:

- hypothesis installed: re-exports the real ``given``/``settings``/``st``.
- hypothesis missing: ``@given(...)`` replaces the test with one that
  skips with an explicit reason, ``@settings(...)`` is the identity, and
  ``st`` is a stub whose attribute accesses / calls all return the stub
  so module-level strategy definitions still evaluate.

Import as ``from _hypothesis_support import HAVE_HYPOTHESIS, given,
settings, st`` instead of importing hypothesis directly.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the extra
    HAVE_HYPOTHESIS = False

    class _StubStrategy:
        """Absorbs any strategy-construction expression (st.lists(st.integers(0, 5)),
        st.text(alphabet=...), strategy.map(f), a | b, ...) without executing it."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

        def __or__(self, other):
            return self

        def __ror__(self, other):
            return self

    st = _StubStrategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (requirements-dev.txt)"
        )

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn

        return decorate


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
