"""Hypothesis property tests for the session KV layer: SessionCachePool
stats invariants (hits + misses == match calls, capacity bound, monotone
counters) and PagedKVAllocator free-list/refcount accounting under random
op sequences."""

import pytest

from _hypothesis_support import given, settings, st

from repro.models import ModelConfig
from repro.serving import CacheEntry, PagedKVAllocator, SessionCachePool
from repro.serving.paged_kv import SCRATCH_PAGE

_op = st.tuples(
    st.sampled_from(["put", "put_low", "match", "peek", "invalidate"]),
    st.integers(0, 3),
    st.lists(st.integers(0, 5), min_size=1, max_size=6),
)


@settings(max_examples=60, deadline=None)
@given(st.lists(_op, max_size=40))
def test_pool_stats_invariants(ops):
    """hits + misses == match calls; entry count bounded by capacity;
    eviction/invalidation counters only grow; peek never perturbs stats."""
    pool = SessionCachePool(capacity=3)
    match_calls = 0
    for op, ki, ids in ops:
        key = f"k{ki}"
        before = (pool.hits, pool.misses, pool.evictions, pool.invalidations)
        if op == "put":
            pool.put(key, CacheEntry(list(ids), []))
        elif op == "put_low":
            pool.put(key, CacheEntry(list(ids), [], source="prime"),
                     low_priority=True)
        elif op == "match":
            match_calls += 1
            entry, usable = pool.match(key, list(ids))
            assert (entry is None) == (usable == 0)
            if entry is not None:
                assert 0 < usable <= min(entry.pos, len(ids))
        elif op == "peek":
            pool.peek(key)
            assert (pool.hits, pool.misses, pool.evictions,
                    pool.invalidations) == before
        else:
            pool.invalidate(key)
        assert pool.hits + pool.misses == match_calls
        assert len(pool) <= pool.capacity
        assert pool.evictions >= before[2] and pool.invalidations >= before[3]


_micro_cfg = ModelConfig(
    name="micro", arch_type="dense", n_layers=1, d_model=16, n_heads=2,
    n_kv_heads=1, d_ff=16, vocab_size=128, param_dtype="float32",
    compute_dtype="float32",
)


@settings(max_examples=40, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["alloc", "decref", "incref"]), st.integers(0, 6)),
    max_size=30,
))
def test_allocator_accounting_invariants(ops):
    """used + free == allocatable; a failed alloc leaves the free list
    untouched; live pages are never the scratch page; used_pages counts
    exactly the distinct live pages."""
    alloc = PagedKVAllocator(_micro_cfg, page_size=4, n_pages=6)
    held = []
    for op, k in ops:
        if op == "alloc":
            got = alloc.alloc(k)
            if got is not None:
                held.extend(got)
            else:
                assert alloc.n_free < k  # only refused when short of pages
        elif op == "decref" and held:
            alloc.decref([held.pop(k % len(held))])
        elif op == "incref" and held:
            p = held[k % len(held)]
            alloc.incref([p])
            held.append(p)
        assert alloc.used_pages + alloc.n_free == alloc.n_pages - 1
        assert SCRATCH_PAGE not in held
        assert all(alloc.refcount(p) >= 1 for p in set(held))
        assert alloc.used_pages == len(set(held))
